//! Chaos tests for the fault-tolerant serving layer (no artifacts
//! needed): drive the EXACT supervised worker loop the server runs
//! (`worker_loop`) with synthetic [`GroupWorker`] executors and injected
//! faults, and assert the resilience contract — a panic fails only its
//! own group's lanes, deadlines drop queued work with 504 and mark
//! partial generations, repeated poison requests quarantine, overload
//! sheds, and drain finishes everything in flight before exit.
//!
//! Every test is gated on the `fault-inject` feature (this binary is
//! empty without it): `cargo test --features fault-inject --test chaos`.
#![cfg(feature = "fault-inject")]

use eagle_serve::coordinator::request::{Request, Response};
use eagle_serve::coordinator::{
    AdmittedGroup, CheckpointStore, LaneCheckpoint, RequestQueue, Scheduler,
};
use eagle_serve::metrics::registry::parse_exposition;
use eagle_serve::metrics::GenRecord;
use eagle_serve::server::{
    deliver, fingerprint, should_shed, worker_loop, GroupWorker, Health, PendingMap, PreemptCtl,
    ServerMetrics, Slot, QUARANTINE_AFTER,
};
use eagle_serve::util::failpoint::{self, Action};
use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

/// Failpoint sites are process-global, and every test here pushes the
/// worker loop through the `sched-dispatch`/`deliver` sites — so tests
/// that arm a site must not overlap tests that would trip it. One lock
/// serializes the whole binary (poison from a failed test is ignored:
/// the guard protects ordering, not data).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn req(id: u64, prompt: &str, deadline_ms: Option<u64>) -> Request {
    let mut r = Request::synthetic(id);
    r.prompt = prompt.into();
    r.deadline_ms = deadline_ms;
    r
}

/// Register a pending slot for `id`, the way the route thread does
/// before pushing to the queue.
fn register(pending: &PendingMap, id: u64) -> Slot {
    let slot: Slot = std::sync::Arc::new((Mutex::new(None), Condvar::new()));
    pending.lock().unwrap().insert(id, slot.clone());
    slot
}

fn taken(slot: &Slot) -> Response {
    slot.0.lock().unwrap().take().expect("slot was delivered")
}

/// Synthetic group executor: echoes each request, panics on prompts
/// named "poison", marks prompts named "partial" deadline-truncated —
/// the engine contract without an engine.
struct ScriptedWorker<'a> {
    pending: &'a PendingMap,
    runs: usize,
    rebuilds: usize,
}

impl GroupWorker for ScriptedWorker<'_> {
    fn run(&mut self, group: AdmittedGroup) {
        self.runs += 1;
        for r in &group.requests {
            if r.prompt == "poison" {
                panic!("synthetic poison request");
            }
            let truncated = if r.prompt == "partial" { Some("deadline") } else { None };
            deliver(
                self.pending,
                r.id,
                Response {
                    id: r.id,
                    text: format!("echo:{}", r.prompt),
                    tokens: 1,
                    target_passes: 1,
                    tau: 1.0,
                    latency_ms: 1.0,
                    queue_ms: 0.0,
                    status: 200,
                    truncated,
                },
            );
        }
    }

    fn rebuild(&mut self) {
        self.rebuilds += 1;
    }
}

/// One closed, pre-loaded serving fixture: the scheduler drains the
/// queue group by group and `worker_loop` returns — exactly the drain
/// path, reused by every test.
fn drain_with(reqs: Vec<Request>) -> (ServerMetrics, PendingMap, Vec<(u64, Slot)>, usize, usize) {
    let queue = RequestQueue::new(64);
    let sched = Scheduler::new(1, 0);
    let pending: PendingMap = Mutex::new(HashMap::new());
    let metrics = ServerMetrics::new(16);
    let health = Health::new(60_000);
    let slots: Vec<(u64, Slot)> = reqs.iter().map(|r| (r.id, register(&pending, r.id))).collect();
    for r in reqs {
        queue.push(r).unwrap();
    }
    queue.close(); // drain: queued work still comes out of pop
    let mut w = ScriptedWorker { pending: &pending, runs: 0, rebuilds: 0 };
    worker_loop(&queue, &sched, &pending, &metrics, &health, 0, None, &mut w);
    let (runs, rebuilds) = (w.runs, w.rebuilds);
    (metrics, pending, slots, runs, rebuilds)
}

#[test]
fn injected_panic_fails_only_its_own_group() {
    let _g = serial();
    let (metrics, pending, slots, runs, rebuilds) =
        drain_with(vec![req(1, "poison", None), req(2, "ok", None)]);
    // the poisoned group's lane gets a 500 instead of a hung slot…
    let r1 = taken(&slots[0].1);
    assert_eq!(r1.status, 500);
    assert!(r1.text.contains("panic"), "names the failure: {}", r1.text);
    // …and the SAME worker serves the next request normally
    let r2 = taken(&slots[1].1);
    assert_eq!(r2.status, 200);
    assert_eq!(r2.text, "echo:ok");
    assert_eq!(runs, 2, "both groups reached the executor");
    assert_eq!(rebuilds, 1, "round state rebuilt exactly once");
    assert!(pending.lock().unwrap().is_empty(), "no slot leaked");
    let exp = parse_exposition(&metrics.render()).unwrap();
    assert_eq!(exp.value("eagle_worker_panics_total"), Some(1.0));
    assert_eq!(exp.value("eagle_lane_failures_total"), Some(1.0));
}

#[test]
fn repeated_poison_is_quarantined_without_execution() {
    let _g = serial();
    // the same poison content resubmitted under fresh ids: after
    // QUARANTINE_AFTER consecutive panics it is refused on sight
    let n = QUARANTINE_AFTER as u64;
    let reqs: Vec<Request> = (1..=n + 1).map(|id| req(id, "poison", None)).collect();
    assert!(
        reqs.windows(2).all(|p| fingerprint(&p[0]) == fingerprint(&p[1])),
        "quarantine keys on content, not id"
    );
    let (metrics, _pending, slots, runs, _) = drain_with(reqs);
    for (_, slot) in slots.iter().take(n as usize) {
        assert_eq!(taken(slot).status, 500);
    }
    let last = taken(&slots[n as usize].1);
    assert_eq!(last.status, 500);
    assert!(last.text.contains("quarantined"), "refusal names the cause: {}", last.text);
    assert_eq!(runs, n as usize, "the quarantined resubmission never executed");
    let exp = parse_exposition(&metrics.render()).unwrap();
    assert_eq!(exp.value("eagle_worker_panics_total"), Some(n as f64));
    assert_eq!(exp.value("eagle_lane_failures_total"), Some(n as f64 + 1.0));
}

#[test]
fn shared_group_members_recover_after_one_success() {
    let _g = serial();
    // a panic then a success for the same content: the failure count
    // resets, so quarantine requires CONSECUTIVE failures
    let mut q = eagle_serve::server::Quarantine::new(2);
    let r = req(1, "flaky", None);
    q.note_failure(fingerprint(&r));
    assert!(!q.is_quarantined(&r));
    q.note_success(fingerprint(&r));
    q.note_failure(fingerprint(&r));
    assert!(!q.is_quarantined(&r), "success cleared the streak");
    q.note_failure(fingerprint(&r));
    assert!(q.is_quarantined(&r));
}

#[test]
fn queue_expired_request_drops_with_504_and_frees_its_slot() {
    let _g = serial();
    // 1 ms budget, 20 ms queue wait: expired before dispatch
    let r = req(7, "late", Some(1));
    std::thread::sleep(std::time::Duration::from_millis(20));
    let (metrics, pending, slots, runs, _) = drain_with(vec![r]);
    let resp = taken(&slots[0].1);
    assert_eq!(resp.status, 504);
    assert_eq!(resp.truncated, Some("deadline"));
    assert!(resp.queue_ms >= 20.0, "reports the real queue wait: {}", resp.queue_ms);
    assert_eq!(runs, 0, "expired work never reaches the engines");
    assert!(pending.lock().unwrap().is_empty(), "slot freed");
    let exp = parse_exposition(&metrics.render()).unwrap();
    let fam = exp.family("eagle_deadline_expired_total").expect("deadline family");
    let queue_stage =
        fam.samples.iter().find(|s| s.label("stage") == Some("queue")).expect("queue stage");
    assert_eq!(queue_stage.value, 1.0);
}

#[test]
fn deadline_truncated_generation_reaches_the_client_and_the_counters() {
    let _g = serial();
    // the engine contract: an expired deadline returns partial output
    // marked truncated; the worker forwards the marker to the client
    let (_, pending, slots, _, _) = drain_with(vec![req(3, "partial", None)]);
    let resp = taken(&slots[0].1);
    assert_eq!(resp.status, 200, "partial output is still an answer");
    assert_eq!(resp.truncated, Some("deadline"));
    assert!(
        resp.to_json().to_string().contains("\"truncated\":\"deadline\""),
        "marker serialized for the client"
    );
    assert!(pending.lock().unwrap().is_empty());
    // and the generate-stage expiry counter keys off the record marker
    let m = ServerMetrics::new(8);
    let mut rec = GenRecord::new(4);
    rec.tokens = vec![1, 2];
    rec.wall_ns = 50_000_000;
    rec.truncated = Some("deadline");
    m.record_gen(&rec, 0.0, 0.05, 1);
    let exp = parse_exposition(&m.render()).unwrap();
    let fam = exp.family("eagle_deadline_expired_total").unwrap();
    let gen_stage =
        fam.samples.iter().find(|s| s.label("stage") == Some("generate")).expect("generate stage");
    assert_eq!(gen_stage.value, 1.0);
}

#[test]
fn overload_sheds_when_the_queue_cannot_meet_the_deadline() {
    let _g = serial();
    // unbounded requests and cold servers never shed
    assert_eq!(should_shed(100, 2.0, None), None);
    assert_eq!(should_shed(100, 0.0, Some(1.0)), None);
    // 10 queued × 1 s EWMA against a 2 s budget: shed, and the estimate
    // is the client's Retry-After hint
    assert_eq!(should_shed(10, 1.0, Some(2.0)), Some(10.0));
    assert_eq!(should_shed(1, 1.0, Some(2.0)), None, "within budget admits");
    // the EWMA feeding the decision comes from served generations
    let m = ServerMetrics::new(8);
    assert_eq!(m.est_service_secs(), 0.0);
    let mut rec = GenRecord::new(4);
    rec.tokens = vec![1];
    rec.wall_ns = 100_000_000; // 100 ms
    m.record_gen(&rec, 0.0, 0.1, 1);
    assert!((m.est_service_secs() - 0.1).abs() < 1e-9, "first sample seeds the EWMA");
    // derived gauges publish the robustness surface at scrape time
    m.on_request();
    m.on_shed();
    m.refresh_derived();
    let exp = parse_exposition(&m.render()).unwrap();
    assert_eq!(exp.value("eagle_shed_total"), Some(1.0));
    assert_eq!(exp.value("eagle_shed_rate"), Some(1.0));
    assert!((exp.value("eagle_est_service_seconds").unwrap() - 0.1).abs() < 1e-9);
}

#[test]
fn drain_finishes_every_queued_request_then_exits() {
    let _g = serial();
    // close-then-drain: all three queued requests still complete, the
    // loop returns (serve() joins the worker and exits cleanly)
    let reqs = vec![req(1, "a", None), req(2, "b", None), req(3, "c", None)];
    let (_, pending, slots, runs, _) = drain_with(reqs);
    assert_eq!(runs, 3);
    for (id, slot) in &slots {
        let r = taken(slot);
        assert_eq!(r.status, 200, "request {id} finished during drain");
    }
    assert!(pending.lock().unwrap().is_empty());
}

/// Synthetic executor for preemption chaos: a first-pass "suspend"
/// prompt is parked in the checkpoint store and re-enqueued as a resume
/// entry (unless the `checkpoint` failpoint eats the park, in which
/// case the lane simply runs to completion); a resumed entry picks its
/// partial back up and finishes, reporting how many tokens it carried.
struct PreemptingWorker<'a> {
    pending: &'a PendingMap,
    queue: &'a RequestQueue,
    ctl: &'a PreemptCtl,
    runs: usize,
}

impl GroupWorker for PreemptingWorker<'_> {
    fn run(&mut self, group: AdmittedGroup) {
        self.runs += 1;
        for r in &group.requests {
            if r.prompt == "suspend" && !r.resume && !failpoint::hit("checkpoint") {
                let mut ck = Box::new(LaneCheckpoint::new());
                ck.id = r.id;
                ck.rec.tokens = vec![7, 8, 9]; // partial progress so far
                ck.kv_target = vec![0.0; 512];
                ck.kv_resident = true;
                self.ctl.store.insert(ck);
                self.queue.push_resume(r.clone());
                continue;
            }
            let carried = match self.ctl.store.take(r.id) {
                Some(ck) if r.resume => ck.rec.tokens.len(),
                _ => 0,
            };
            deliver(
                self.pending,
                r.id,
                Response {
                    id: r.id,
                    text: format!("done:{}:{carried}", r.prompt),
                    tokens: carried + 1,
                    target_passes: 1,
                    tau: 1.0,
                    latency_ms: 1.0,
                    queue_ms: 0.0,
                    status: 200,
                    truncated: None,
                },
            );
        }
    }

    fn rebuild(&mut self) {}
}

#[test]
fn preempt_storm_completes_every_lane_without_quarantine() {
    let _g = serial();
    let queue = RequestQueue::new(64);
    let sched = Scheduler::new(1, 0);
    let pending: PendingMap = Mutex::new(HashMap::new());
    let metrics = ServerMetrics::new(16);
    let health = Health::new(60_000);
    // 2 KV slots with a watermark of 1: the storm of parked residents
    // keeps the store under pressure, so eviction runs during the storm
    let ctl = PreemptCtl::new(true, CheckpointStore::new(2, 1, 0));
    // six identical "suspend" lanes (same fingerprint — a quarantine
    // counter that treated suspension as failure would trip here) plus
    // two plain lanes; the 3rd park attempt is eaten by the failpoint
    // and that lane must run to completion instead
    failpoint::set("checkpoint", Action::Degenerate, 3);
    let reqs: Vec<Request> =
        (1..=8).map(|id| req(id, if id <= 6 { "suspend" } else { "plain" }, None)).collect();
    let slots: Vec<(u64, Slot)> = reqs.iter().map(|r| (r.id, register(&pending, r.id))).collect();
    for r in reqs {
        queue.push(r).unwrap();
    }
    queue.close();
    let mut w = PreemptingWorker { pending: &pending, queue: &queue, ctl: &ctl, runs: 0 };
    worker_loop(&queue, &sched, &pending, &metrics, &health, 0, Some(&ctl), &mut w);
    failpoint::clear_all();
    let mut carried3 = 0;
    for (id, slot) in &slots {
        let resp = taken(slot);
        assert_eq!(resp.status, 200, "lane {id} must complete, not hang or 500: {}", resp.text);
        if resp.text.ends_with(":3") {
            carried3 += 1;
        }
    }
    assert_eq!(carried3, 5, "5 of 6 suspensions parked and resumed with their partial");
    assert!(ctl.store.evictions() >= 1, "the storm must cross the KV watermark");
    assert!(ctl.store.is_empty(), "every checkpoint was consumed by a resume");
    assert!(pending.lock().unwrap().is_empty(), "no slot leaked");
    let exp = parse_exposition(&metrics.render()).unwrap();
    assert_eq!(
        exp.value("eagle_worker_panics_total").unwrap_or(0.0),
        0.0,
        "suspension is not a failure"
    );
}

#[test]
fn drain_delivers_parked_checkpoints_instead_of_stranding() {
    let _g = serial();
    // a suspension whose requeue was lost (fault injection): only the
    // parked checkpoint knows the lane exists. Drain must deliver its
    // partial, not strand the waiter.
    let queue = RequestQueue::new(8);
    let sched = Scheduler::new(1, 0);
    let pending: PendingMap = Mutex::new(HashMap::new());
    let metrics = ServerMetrics::new(16);
    let health = Health::new(60_000);
    let ctl = PreemptCtl::new(true, CheckpointStore::new(4, 0, 0));
    let slot = register(&pending, 9);
    let mut ck = Box::new(LaneCheckpoint::new());
    ck.id = 9;
    ck.rec.tokens = vec![1, 2, 3, 4];
    ctl.store.insert(ck);
    queue.close();
    let mut w = ScriptedWorker { pending: &pending, runs: 0, rebuilds: 0 };
    worker_loop(&queue, &sched, &pending, &metrics, &health, 0, Some(&ctl), &mut w);
    let resp = taken(&slot);
    assert_eq!(resp.status, 200);
    assert_eq!(resp.truncated, Some("drain"));
    assert_eq!(resp.tokens, 4, "the partial carries the pre-suspension tokens");
    assert_eq!(w.runs, 0, "nothing was queued — only the safety net ran");
    assert!(ctl.store.is_empty());
    assert!(pending.lock().unwrap().is_empty());
}

#[test]
fn deadline_expired_while_suspended_delivers_partial_not_504() {
    let _g = serial();
    let queue = RequestQueue::new(8);
    let sched = Scheduler::new(1, 0);
    let pending: PendingMap = Mutex::new(HashMap::new());
    let metrics = ServerMetrics::new(16);
    let health = Health::new(60_000);
    let ctl = PreemptCtl::new(true, CheckpointStore::new(4, 0, 0));
    let slot = register(&pending, 4);
    let mut ck = Box::new(LaneCheckpoint::new());
    ck.id = 4;
    ck.rec.tokens = vec![5, 6];
    ctl.store.insert(ck);
    // the resume entry waits out its whole 1 ms budget in the queue
    queue.push_resume(req(4, "late", Some(1)));
    std::thread::sleep(std::time::Duration::from_millis(20));
    queue.close();
    let mut w = ScriptedWorker { pending: &pending, runs: 0, rebuilds: 0 };
    worker_loop(&queue, &sched, &pending, &metrics, &health, 0, Some(&ctl), &mut w);
    let resp = taken(&slot);
    assert_eq!(resp.status, 200, "partial output is still an answer");
    assert_eq!(resp.truncated, Some("deadline"));
    assert_eq!(resp.tokens, 2);
    assert!(resp.queue_ms >= 20.0, "reports the real queue wait: {}", resp.queue_ms);
    assert_eq!(w.runs, 0, "the expired lane never re-entered the engines");
    assert!(ctl.store.is_empty(), "expiry consumed the checkpoint");
    let exp = parse_exposition(&metrics.render()).unwrap();
    let fam = exp.family("eagle_deadline_expired_total").expect("deadline family");
    let queue_stage =
        fam.samples.iter().find(|s| s.label("stage") == Some("queue")).expect("queue stage");
    assert_eq!(queue_stage.value, 1.0);
}

#[test]
fn armed_failpoint_panics_are_supervised_like_any_other() {
    let _g = serial();
    // arm the dispatch-path site: the first group panics inside the
    // supervised closure (before the executor), the second sails through
    failpoint::set("sched-dispatch", Action::Panic, 1);
    let (metrics, _, slots, runs, rebuilds) =
        drain_with(vec![req(1, "a", None), req(2, "b", None)]);
    failpoint::clear_all();
    assert_eq!(taken(&slots[0].1).status, 500);
    assert_eq!(taken(&slots[1].1).status, 200);
    assert_eq!(runs, 1, "the panicked group never reached the executor");
    assert_eq!(rebuilds, 1);
    let exp = parse_exposition(&metrics.render()).unwrap();
    assert_eq!(exp.value("eagle_worker_panics_total"), Some(1.0));
}
