//! End-to-end integration tests over the real AOT artifacts.
//!
//! THE core test is losslessness: at T=0, every speculative engine must
//! produce token-identical output to vanilla greedy decoding (the paper's
//! central guarantee). Skipped gracefully when `make artifacts` hasn't run.

use eagle_serve::coordinator::request::Method;
use eagle_serve::eval::runner::{Runner, RunSpec};
use eagle_serve::eval::Workload;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::spec::dyntree::{DynTreeConfig, TreePolicy, WidthSelect};
use eagle_serve::spec::engine::GenConfig;
use eagle_serve::text::bpe::Bpe;

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn setup() -> (Runner, Bpe) {
    let runner = Runner::new(&artifacts_dir()).expect("runner");
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap()).expect("vocab");
    (runner, bpe)
}

#[test]
fn eagle_tree_is_lossless_at_t0() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let cfg = GenConfig { max_new: 40, temperature: 0.0, seed: 3, eos: None };
    for p in wl.take(5) {
        let van = runner
            .run_one(
                &bundle,
                &p.ids,
                &RunSpec { method: Method::Vanilla, ..Default::default() },
                &cfg,
            )
            .unwrap();
        let eag = runner.run_one(&bundle, &p.ids, &RunSpec::default(), &cfg).unwrap();
        assert_eq!(van.tokens, eag.tokens, "greedy mismatch on '{}'", p.text);
        assert!(eag.tau() > 1.5, "tree tau unexpectedly low: {}", eag.tau());
        assert!(eag.target_passes < van.target_passes / 2);
    }
}

#[test]
fn eagle_chain_and_baselines_lossless_at_t0() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], true, true).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "gsm8k", runner.man.constants.prefill_p).unwrap();
    let cfg = GenConfig { max_new: 32, temperature: 0.0, seed: 5, eos: None };
    for p in wl.take(3) {
        let van = runner
            .run_one(
                &bundle,
                &p.ids,
                &RunSpec { method: Method::Vanilla, ..Default::default() },
                &cfg,
            )
            .unwrap();
        for m in [Method::EagleChain, Method::Medusa, Method::Lookahead, Method::ClassicSpec] {
            let rec = runner
                .run_one(&bundle, &p.ids, &RunSpec { method: m, ..Default::default() }, &cfg)
                .unwrap();
            assert_eq!(van.tokens, rec.tokens, "{} diverged from greedy on '{}'", m.name(), p.text);
        }
    }
}

#[test]
fn draft_variants_all_lossless_at_t0() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle = ModelBundle::load(
        &runner.rt, &runner.man, "toy-s", &["eagle", "unshift", "feat", "tok", "eagle_gen"],
        false, false,
    )
    .unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let cfg = GenConfig { max_new: 24, temperature: 0.0, seed: 11, eos: None };
    let p = &wl.prompts[1];
    let van = runner
        .run_one(
            &bundle,
            &p.ids,
            &RunSpec { method: Method::Vanilla, ..Default::default() },
            &cfg,
        )
        .unwrap();
    for v in ["eagle", "unshift", "feat", "tok", "eagle_gen"] {
        let spec = RunSpec { method: Method::EagleChain, variant: v.into(), ..Default::default() };
        let rec = runner.run_one(&bundle, &p.ids, &spec, &cfg).unwrap();
        assert_eq!(van.tokens, rec.tokens, "variant {v} diverged");
    }
}

#[test]
fn t1_sampling_runs_and_matches_seed_determinism() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let cfg = GenConfig { max_new: 24, temperature: 1.0, seed: 9, eos: None };
    let p = &wl.prompts[0];
    let spec = RunSpec { temperature: 1.0, ..Default::default() };
    let a = runner.run_one(&bundle, &p.ids, &spec, &cfg).unwrap();
    let b = runner.run_one(&bundle, &p.ids, &spec, &cfg).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must reproduce");
    assert!(!a.tokens.is_empty());
}

#[test]
fn width_selection_is_lossless_and_bounded() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let c = &runner.man.constants;
    let cfg = GenConfig { max_new: 32, temperature: 0.0, seed: 3, eos: None };
    let p = &wl.prompts[0];
    // auto width selection (static + dynamic trees) vs the legacy path
    // pinned to the full tree_t executable: token-identical greedy output
    let pinned = RunSpec { verify_width: WidthSelect::Fixed(c.tree_t), ..Default::default() };
    let fixed = runner.run_one(&bundle, &p.ids, &pinned, &cfg).unwrap();
    let auto = runner.run_one(&bundle, &p.ids, &RunSpec::default(), &cfg).unwrap();
    assert_eq!(auto.tokens, fixed.tokens, "width auto-selection changed greedy output");
    assert!(fixed.round_verify_t.iter().all(|&t| t == c.tree_t), "pin must hold");
    assert!(auto.round_verify_t.iter().all(|&t| t <= c.tree_t), "auto never exceeds tree_t");
    let dspec =
        RunSpec { tree: TreePolicy::Dynamic(DynTreeConfig::default()), ..Default::default() };
    let dyn_rec = runner.run_one(&bundle, &p.ids, &dspec, &cfg).unwrap();
    assert_eq!(dyn_rec.tokens, fixed.tokens, "dynamic + width selection must stay lossless");
    assert!(dyn_rec.mean_verify_t() > 0.0);
}

#[test]
fn batched_engine_matches_single_lane_results() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let c = &runner.man.constants;
    let cfg = GenConfig { max_new: 20, temperature: 0.0, seed: 7, eos: None };
    let prompts: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|p| p.ids.clone()).collect();
    let be = eagle_serve::coordinator::BatchEagleEngine::new(
        &bundle.target, &bundle.drafts["eagle"], c,
    );
    let recs = be.generate(&prompts, &cfg).unwrap();
    assert_eq!(recs.len(), 2);
    // lock-step batched EAGLE must equal vanilla greedy per lane
    for (i, rec) in recs.iter().enumerate() {
        let van = runner
            .run_one(
                &bundle,
                &prompts[i],
                &RunSpec { method: Method::Vanilla, max_new: 20, ..Default::default() },
                &cfg,
            )
            .unwrap();
        assert_eq!(van.tokens, rec.tokens, "batched lane {i} diverged from greedy");
    }
    // batched vanilla agrees too
    let vrecs = be.vanilla_batch(&prompts, &cfg).unwrap();
    for (i, rec) in vrecs.iter().enumerate() {
        assert_eq!(recs[i].tokens, rec.tokens, "vanilla batch lane {i}");
    }
}

#[test]
fn batched_t1_sampling_matches_equal_seed_bs1_and_is_alloc_free() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let c = &runner.man.constants;
    let prompts: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|p| p.ids.clone()).collect();
    let seeds = [41u64, 1009];
    // policies under test: the static tree always; non-adaptive dynamic
    // only when the bs=1 and bs=2 verify families match (the width plan
    // is family-dependent, and adaptive controllers observe differently
    // per engine — both would change tree shapes, not correctness)
    let mut policies = vec![TreePolicy::default_tree()];
    let fams_match = c
        .verify_widths
        .iter()
        .all(|&t| bundle.target.has_verify(t, 1) == bundle.target.has_verify(t, 2));
    if fams_match {
        policies.push(TreePolicy::Dynamic(DynTreeConfig {
            adaptive: false,
            ..Default::default()
        }));
    }
    for policy in policies {
        let be = eagle_serve::coordinator::BatchEagleEngine::new(
            &bundle.target, &bundle.drafts["eagle"], c,
        )
        .with_policy(policy.clone());
        let cfg = GenConfig { max_new: 24, temperature: 1.0, seed: 0, eos: None };
        let mut pool = eagle_serve::spec::scratch::ScratchPool::new();
        let recs = be.generate_pooled_seeded(&prompts, &seeds, &cfg, &mut pool).unwrap();
        // per-lane equality with the equal-seed bs=1 run: the batched
        // sampled path shares the bs=1 growth + SpecInfer walk and each
        // lane owns its RNG stream, so tokens must be bit-identical
        for (li, rec) in recs.iter().enumerate() {
            let spec = RunSpec { temperature: 1.0, tree: policy.clone(), ..Default::default() };
            let solo = runner
                .run_one(
                    &bundle,
                    &prompts[li],
                    &spec,
                    &GenConfig { seed: seeds[li], ..cfg.clone() },
                )
                .unwrap();
            assert_eq!(
                solo.tokens,
                rec.tokens,
                "lane {li} ({} tree): batched T=1 diverged from equal-seed bs=1",
                policy.name()
            );
            // T>0 rounds are zero-alloc once warm: the q-slab replaced
            // the per-node Rc<Vec<f32>> clones
            assert_eq!(
                rec.steady_host_alloc_bytes(),
                0,
                "lane {li}: sampled steady-state rounds allocated: {:?}",
                rec.round_host_alloc_bytes
            );
            assert_eq!(solo.steady_host_alloc_bytes(), 0, "bs=1 sampled rounds allocated");
        }
        // output is invariant to batch composition: swap the peer lane
        let swapped: Vec<Vec<u32>> = vec![prompts[1].clone(), prompts[0].clone()];
        let sseeds = [seeds[1], seeds[0]];
        let rswapped = be.generate_pooled_seeded(&swapped, &sseeds, &cfg, &mut pool).unwrap();
        assert_eq!(rswapped[1].tokens, recs[0].tokens, "lane output depends on batch position");
        assert_eq!(rswapped[0].tokens, recs[1].tokens, "lane output depends on batch peer");
        // the pool is warm after the first admission: a sampled replay
        // must not allocate host round state at all
        let again = be.generate_pooled_seeded(&prompts, &seeds, &cfg, &mut pool).unwrap();
        for (li, rec) in again.iter().enumerate() {
            assert_eq!(rec.tokens, recs[li].tokens, "warm-pool replay diverged");
            assert!(
                rec.round_host_alloc_bytes.iter().all(|&x| x == 0),
                "lane {li}: warm-pool sampled admission allocated: {:?}",
                rec.round_host_alloc_bytes
            );
        }
    }
}

#[test]
fn width_grouped_execution_is_lossless() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let c = &runner.man.constants;
    let cfg = GenConfig { max_new: 20, temperature: 0.0, seed: 7, eos: None };
    let prompts: Vec<Vec<u32>> = wl.prompts.iter().take(4).map(|p| p.ids.clone()).collect();
    let policy = || TreePolicy::Dynamic(DynTreeConfig::default());
    // FCFS baseline: one bs4 batch at the max over lane fits
    let fcfs = eagle_serve::coordinator::BatchEagleEngine::new(
        &bundle.target, &bundle.drafts["eagle"], c,
    )
    .with_policy(policy())
    .generate(&prompts, &cfg)
    .unwrap();
    // grouped: the same lanes split into capped sub-batches — per-lane
    // greedy outputs must be identical and each group must respect its cap
    let narrow = *c.verify_widths.first().unwrap();
    for (cap, idx) in [(narrow, [1usize, 3]), (c.tree_t, [0, 2])] {
        let gp: Vec<Vec<u32>> = idx.iter().map(|&i| prompts[i].clone()).collect();
        let be = eagle_serve::coordinator::BatchEagleEngine::new(
            &bundle.target, &bundle.drafts["eagle"], c,
        )
        .with_policy(policy())
        .with_verify_cap(cap);
        let recs = be.generate(&gp, &cfg).unwrap();
        for (j, &i) in idx.iter().enumerate() {
            assert_eq!(recs[j].tokens, fcfs[i].tokens, "lane {i} diverged under width grouping");
            assert!(
                recs[j].round_verify_t.iter().all(|&t| t <= cap),
                "lane {i} exceeded its group's width cap {cap}"
            );
        }
    }
}

#[test]
fn round_state_is_allocation_free_after_warmup() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let cfg = GenConfig { max_new: 32, temperature: 0.0, seed: 3, eos: None };
    let p = &wl.prompts[0];
    // bs=1, static and dynamic trees: the scratch is reserved up front,
    // so every round (including the first) should reuse it fully
    for spec in [
        RunSpec::default(),
        RunSpec { tree: TreePolicy::Dynamic(DynTreeConfig::default()), ..Default::default() },
    ] {
        let rec = runner.run_one(&bundle, &p.ids, &spec, &cfg).unwrap();
        assert!(!rec.round_host_alloc_bytes.is_empty(), "alloc metric must be recorded");
        assert_eq!(
            rec.steady_host_alloc_bytes(),
            0,
            "steady-state rounds allocated ({:?} tree): {:?}",
            spec.tree.name(),
            rec.round_host_alloc_bytes
        );
        assert!(
            rec.scratch_reuse_total + 1 >= rec.round_host_alloc_bytes.len() as u64,
            "at most the warm-up round may allocate"
        );
    }
    // batched engine: pool-wide delta recorded per lane, 0 once warm
    let prompts: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|p| p.ids.clone()).collect();
    let be = eagle_serve::coordinator::BatchEagleEngine::new(
        &bundle.target, &bundle.drafts["eagle"], &runner.man.constants,
    );
    for rec in be.generate(&prompts, &cfg).unwrap() {
        assert_eq!(
            rec.steady_host_alloc_bytes(),
            0,
            "batched steady-state rounds allocated: {:?}",
            rec.round_host_alloc_bytes
        );
    }
}

#[test]
fn batched_lane_scratch_pool_reuse_across_admissions_is_clean() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let c = &runner.man.constants;
    let cfg = GenConfig { max_new: 20, temperature: 0.0, seed: 7, eos: None };
    let a: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|p| p.ids.clone()).collect();
    let b: Vec<Vec<u32>> = wl.prompts.iter().skip(2).take(2).map(|p| p.ids.clone()).collect();
    let be = eagle_serve::coordinator::BatchEagleEngine::new(
        &bundle.target, &bundle.drafts["eagle"], c,
    );
    // fresh-pool references for both admissions
    let ref_a = be.generate(&a, &cfg).unwrap();
    let ref_b = be.generate(&b, &cfg).unwrap();
    // one pool across admissions A -> B -> A: lane scratch reuse must
    // not leak state between admissions (bit-identical outputs)
    let mut pool = eagle_serve::spec::scratch::ScratchPool::new();
    let got_a = be.generate_pooled(&a, &cfg, &mut pool).unwrap();
    let got_b = be.generate_pooled(&b, &cfg, &mut pool).unwrap();
    let again_a = be.generate_pooled(&a, &cfg, &mut pool).unwrap();
    for li in 0..2 {
        assert_eq!(got_a[li].tokens, ref_a[li].tokens, "admission A lane {li} diverged");
        assert_eq!(got_b[li].tokens, ref_b[li].tokens, "admission B lane {li} leaked state");
        assert_eq!(again_a[li].tokens, ref_a[li].tokens, "admission A replay diverged");
        // the pool is warm after admission A: later admissions must not
        // allocate host round state at all
        assert!(
            got_b[li].round_host_alloc_bytes.iter().all(|&x| x == 0),
            "warm-pool admission allocated: {:?}",
            got_b[li].round_host_alloc_bytes
        );
    }
}

#[test]
fn moe_and_quant_targets_generate() {
    require_artifacts!();
    let (runner, bpe) = setup();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let p = &wl.prompts[0];
    let cfg = GenConfig { max_new: 16, temperature: 0.0, seed: 1, eos: None };
    for model in ["toy-moe", "toy-s-int8"] {
        let bundle =
            ModelBundle::load(&runner.rt, &runner.man, model, &["eagle"], false, false).unwrap();
        let van = runner
            .run_one(
                &bundle,
                &p.ids,
                &RunSpec { method: Method::Vanilla, ..Default::default() },
                &cfg,
            )
            .unwrap();
        let eag = runner.run_one(&bundle, &p.ids, &RunSpec::default(), &cfg).unwrap();
        assert_eq!(van.tokens, eag.tokens, "{model} not lossless");
    }
}

#[test]
fn tokenizer_fixtures_match_python() {
    // cross-language BPE contract (fixtures dumped by python tests)
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/tokenizer_cases.json");
    if !path.exists() {
        eprintln!("skipping: fixtures not dumped yet (run pytest)");
        return;
    }
    let text = std::fs::read_to_string(path).unwrap();
    let v = eagle_serve::util::json::Json::parse(&text).unwrap();
    let bpe = Bpe::from_json(&v.req("vocab").unwrap().to_string()).unwrap();
    for case in v.req("cases").unwrap().as_arr().unwrap() {
        let t = case.req("text").unwrap().as_str().unwrap();
        let ids: Vec<u32> = case
            .req("ids")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap() as u32)
            .collect();
        assert_eq!(bpe.encode(t), ids, "encode mismatch on {t:?}");
        assert_eq!(bpe.decode(&ids), t, "decode mismatch on {t:?}");
    }
}
