//! Property tests on coordinator invariants (S15/S19): slot allocation,
//! queue FIFO/backpressure under random op sequences, scheduler batching.

use eagle_serve::coordinator::kvslots::SlotAllocator;
use eagle_serve::coordinator::queue::{PushError, RequestQueue};
use eagle_serve::coordinator::request::Request;
use eagle_serve::util::prop::check;

fn req(id: u64) -> Request {
    Request::synthetic(id)
}

#[test]
fn prop_slot_allocator_never_double_allocates() {
    check("slots", 100, |rng, _| {
        let cap = 1 + rng.below(16);
        let mut a = SlotAllocator::new(cap);
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..200 {
            if rng.f32() < 0.55 {
                if let Some(s) = a.alloc() {
                    assert!(!held.contains(&s), "slot {s} handed out twice");
                    assert!(s < cap);
                    held.push(s);
                } else {
                    assert_eq!(held.len(), cap, "alloc failed below capacity");
                }
            } else if !held.is_empty() {
                let i = rng.below(held.len());
                let s = held.swap_remove(i);
                a.release(s);
            }
            assert_eq!(a.available(), cap - held.len());
            for &s in &held {
                assert!(a.is_allocated(s));
            }
        }
    });
}

#[test]
fn prop_queue_preserves_fifo_under_interleaving() {
    check("queue fifo", 50, |rng, _| {
        let cap = 4 + rng.below(12);
        let q = RequestQueue::new(cap);
        let mut next_push = 0u64;
        let mut next_pop = 0u64;
        for _ in 0..300 {
            if rng.f32() < 0.6 {
                match q.push(req(next_push)) {
                    Ok(()) => next_push += 1,
                    Err(PushError::Full) => assert_eq!(q.len(), cap),
                    Err(PushError::Closed) => unreachable!(),
                }
            } else {
                let got = q.pop_up_to(1);
                if let Some(r) = got.first() {
                    assert_eq!(r.id, next_pop, "FIFO violated");
                    next_pop += 1;
                } else {
                    assert_eq!(q.len(), 0);
                }
            }
            assert!(q.len() <= cap);
        }
    });
}

#[test]
fn prop_pop_up_to_respects_bounds() {
    check("batch pop", 50, |rng, _| {
        let q = RequestQueue::new(64);
        let n = rng.below(20);
        for i in 0..n {
            q.push(req(i as u64)).unwrap();
        }
        let k = rng.below(24);
        let batch = q.pop_up_to(k);
        assert_eq!(batch.len(), k.min(n));
        // order within the batch is arrival order
        for w in batch.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        assert_eq!(q.len(), n - batch.len());
    });
}
