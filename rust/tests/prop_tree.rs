//! Property tests on draft-tree invariants (S11/S19): random trees must
//! have consistent topology, ancestor closures, verify masks, and greedy
//! walks that always return valid root-paths.

use eagle_serve::spec::tree::{DraftTree, TreeSpec};
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

fn random_tree(rng: &mut Rng, max_nodes: usize) -> DraftTree {
    let mut t = DraftTree::with_root(rng.below(100) as u32);
    let n = 1 + rng.below(max_nodes.max(2) - 1);
    for _ in 0..n {
        let parent = rng.below(t.len());
        t.add(parent, rng.below(100) as u32, -rng.f32(), None);
    }
    t
}

#[test]
fn prop_depth_is_parent_depth_plus_one() {
    check("depth", 200, |rng, _| {
        let t = random_tree(rng, 24);
        for (i, n) in t.nodes.iter().enumerate() {
            match n.parent {
                None => assert_eq!(n.depth, 0),
                Some(p) => {
                    assert!(p < i, "parent must precede child");
                    assert_eq!(n.depth, t.nodes[p].depth + 1);
                }
            }
        }
    });
}

#[test]
fn prop_ancestor_closure_contains_path_exactly() {
    check("ancestors", 200, |rng, _| {
        let t = random_tree(rng, 24);
        let i = rng.below(t.len());
        let mask = t.ancestor_mask(i);
        let path = t.path(i);
        let from_mask: Vec<usize> = (0..t.len()).filter(|&j| mask[j]).collect();
        let mut sorted_path = path.clone();
        sorted_path.sort_unstable();
        assert_eq!(from_mask, sorted_path);
        assert_eq!(path[0], 0, "path starts at root");
        assert_eq!(*path.last().unwrap(), i);
    });
}

#[test]
fn prop_verify_bias_rows_allow_prefix_and_ancestors_only() {
    check("verify bias", 100, |rng, _| {
        let t = random_tree(rng, 16);
        let t_pad = 24;
        let cache_len = 8 + rng.below(16);
        let s = cache_len + t_pad + 4 + rng.below(8);
        let (_tokens, pos, bias) = t.verify_inputs(t_pad, cache_len, s);
        for i in 0..t.len() {
            let row = &bias[i * s..(i + 1) * s];
            let anc = t.ancestor_mask(i);
            for j in 0..s {
                let visible = row[j] == 0.0;
                let expect = j < cache_len
                    || (j >= cache_len && j < cache_len + t.len() && anc[j - cache_len]);
                assert_eq!(visible, expect, "node {i} col {j}");
            }
            assert_eq!(pos[i] as usize, cache_len + t.nodes[i].depth);
            // self always visible => softmax never NaN
            assert_eq!(row[cache_len + i], 0.0);
        }
        // padding rows have exactly one visible column
        for i in t.len()..t_pad {
            let row = &bias[i * s..(i + 1) * s];
            assert_eq!(row.iter().filter(|&&x| x == 0.0).count(), 1);
        }
    });
}

#[test]
fn prop_greedy_walk_is_valid_root_path() {
    check("greedy walk", 200, |rng, _| {
        let t = random_tree(rng, 20);
        // random argmax oracle
        let picks: Vec<usize> = (0..t.len()).map(|_| rng.below(100)).collect();
        let path = t.greedy_walk(|i| picks[i]);
        assert_eq!(path[0], 0);
        for w in path.windows(2) {
            assert_eq!(t.nodes[w[1]].parent, Some(w[0]), "path edge must be parent-child");
            assert_eq!(t.nodes[w[1]].token as usize, picks[w[0]], "walk must follow argmax");
        }
        // maximality: the walk stops only when no child matches
        let last = *path.last().unwrap();
        assert!(!t
            .children(last)
            .iter()
            .any(|&c| t.nodes[c].token as usize == picks[last]));
    });
}

#[test]
fn prop_tree_spec_node_budget() {
    check("tree spec", 50, |rng, _| {
        let depth = 1 + rng.below(5);
        let widths: Vec<usize> = (0..depth).map(|_| 1 + rng.below(8)).collect();
        let spec = TreeSpec { level_widths: widths.clone(), branch: 1 + rng.below(4) };
        assert_eq!(spec.total_nodes(), 1 + widths.iter().sum::<usize>());
        assert_eq!(spec.depth(), depth);
        assert_eq!(spec.is_chain(), widths.iter().all(|&w| w == 1));
    });
}

#[test]
fn prop_random_dists_valid() {
    check("dist helper", 100, |rng, _| {
        let n = 1 + rng.below(50);
        let d = random_dist(rng, n);
        assert!((d.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    });
}
