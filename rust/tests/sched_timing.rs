//! Timing-sensitive scheduler/queue tests: linger admission latency and
//! condvar wakeup promptness. These depend on wall-clock behavior, so
//! they are `#[ignore]`d in the default parallel `cargo test` run and
//! executed serially by a dedicated CI step:
//!
//!   cargo test -q --test sched_timing -- --ignored --test-threads=1

use std::sync::Arc;
use std::time::{Duration, Instant};

use eagle_serve::coordinator::queue::RequestQueue;
use eagle_serve::coordinator::request::Request;
use eagle_serve::coordinator::Scheduler;

fn req(id: u64) -> Request {
    Request::synthetic(id)
}

/// A late arrival wakes the lingering scheduler through the queue
/// condvar: the batch fills and admits well before the linger deadline
/// (the old 1 ms sleep-poll quantized this to the tick, and a longer
/// tick would have delayed admission by the full tick).
#[test]
#[ignore = "timing-sensitive: run serially in the dedicated CI step"]
fn linger_admits_on_arrival_not_on_deadline() {
    let q = Arc::new(RequestQueue::new(16));
    q.push(req(0)).unwrap();
    let q2 = q.clone();
    let pusher = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(10));
        q2.push(req(1)).unwrap();
        q2.push(req(2)).unwrap();
    });
    let sched = Scheduler::new(3, 500);
    let t0 = Instant::now();
    let batch = sched.next_batch(&q);
    let elapsed = t0.elapsed();
    pusher.join().unwrap();
    assert_eq!(batch.len(), 3);
    assert!(
        elapsed < Duration::from_millis(250),
        "admission waited toward the deadline ({elapsed:?}) instead of waking on arrival"
    );
}

/// Closing the queue mid-linger releases the partial batch immediately.
#[test]
#[ignore = "timing-sensitive: run serially in the dedicated CI step"]
fn close_releases_partial_batch_before_deadline() {
    let q = Arc::new(RequestQueue::new(16));
    q.push(req(0)).unwrap();
    let q2 = q.clone();
    let closer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(10));
        q2.close();
    });
    let sched = Scheduler::new(4, 500);
    let t0 = Instant::now();
    let batch = sched.next_batch(&q);
    let elapsed = t0.elapsed();
    closer.join().unwrap();
    assert_eq!(batch.len(), 1);
    assert!(
        elapsed < Duration::from_millis(250),
        "close did not unblock the linger wait ({elapsed:?})"
    );
}

/// The linger deadline itself still bounds the wait when nothing more
/// arrives: a partial batch is admitted at (roughly) the deadline, not
/// held indefinitely.
#[test]
#[ignore = "timing-sensitive: run serially in the dedicated CI step"]
fn linger_deadline_bounds_the_wait() {
    let q = RequestQueue::new(16);
    q.push(req(0)).unwrap();
    let sched = Scheduler::new(4, 30);
    let t0 = Instant::now();
    let batch = sched.next_batch(&q);
    let elapsed = t0.elapsed();
    assert_eq!(batch.len(), 1);
    assert!(elapsed >= Duration::from_millis(25), "deadline cut short ({elapsed:?})");
    assert!(elapsed < Duration::from_millis(300), "deadline overshot ({elapsed:?})");
}
