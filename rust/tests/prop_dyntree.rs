//! Property tests on the dynamic draft-tree planner (S20/S19): the
//! global rerank must preserve ancestor closure and the node budget for
//! ARBITRARY trees and scores, and must degrade to the static tree shape
//! when draft confidence is uniform. Controller adaptation invariants
//! (bounds, budget immutability) are exercised under random workloads.
//!
//! Verify-width selection (S21) is covered by three laws: the planned
//! width always holds the planned budget (never truncates), verify
//! inputs for the same tree are prefix-identical across widths (so
//! greedy outputs match the fixed-`tree_t` path), and — the empirical
//! law — budget-capped dynamic growth at T>0 commits first tokens
//! distributed exactly as the target distribution, including under a
//! width-downshifted budget (the cap lands BEFORE sampling).

use std::collections::HashSet;
use std::rc::Rc;

use eagle_serve::coordinator::{group_cost, plan_width_groups};
use eagle_serve::spec::dyntree::{
    plan_round_width, rerank, select_frontier, ControllerConfig, DynTreeParams, SpecController,
    WidthFamily,
};
use eagle_serve::spec::sampling::{sample, tree_accept, TreeVerdict};
use eagle_serve::spec::tree::{DraftTree, TreeSpec};
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

fn random_tree(rng: &mut Rng, max_nodes: usize) -> DraftTree {
    let mut t = DraftTree::with_root(rng.below(100) as u32);
    let n = 1 + rng.below(max_nodes.max(2) - 1);
    for _ in 0..n {
        let parent = rng.below(t.len());
        t.add(parent, rng.below(100) as u32, -rng.f32() * 5.0, None);
    }
    t
}

/// Tree with cumulative (monotone non-increasing along paths) scores,
/// like real draft log-probs.
fn random_cumulative_tree(rng: &mut Rng, max_nodes: usize) -> DraftTree {
    let mut t = DraftTree::with_root(rng.below(100) as u32);
    let n = 1 + rng.below(max_nodes.max(2) - 1);
    for _ in 0..n {
        let parent = rng.below(t.len());
        let score = t.nodes[parent].score - (rng.f32() + 1e-3);
        t.add(parent, rng.below(100) as u32, score, None);
    }
    t
}

#[test]
fn prop_rerank_preserves_ancestor_closure() {
    check("rerank closure", 200, |rng, _| {
        let t = random_tree(rng, 40);
        let budget = 1 + rng.below(t.len() + 4);
        let (pruned, kept) = rerank(&t, budget);
        assert_eq!(pruned.len(), kept.len());
        assert_eq!(kept[0], 0, "root is always kept");
        // pruned is a well-formed tree: parents precede children, depths line up
        for (i, n) in pruned.nodes.iter().enumerate() {
            match n.parent {
                None => assert_eq!(i, 0),
                Some(p) => {
                    assert!(p < i, "parent must precede child");
                    assert_eq!(n.depth, pruned.nodes[p].depth + 1);
                }
            }
        }
        // kept maps back to the original: payloads match, closure holds
        let kept_set: HashSet<usize> = kept.iter().copied().collect();
        for (pi, &oi) in kept.iter().enumerate() {
            assert_eq!(pruned.nodes[pi].token, t.nodes[oi].token);
            assert_eq!(pruned.nodes[pi].depth, t.nodes[oi].depth, "depth preserved");
            if let Some(op) = t.nodes[oi].parent {
                assert!(kept_set.contains(&op), "ancestor closure violated at {oi}");
            }
        }
    });
}

#[test]
fn prop_rerank_respects_budget() {
    check("rerank budget", 200, |rng, _| {
        let t = random_tree(rng, 40);
        let budget = 1 + rng.below(t.len() + 4);
        let (pruned, kept) = rerank(&t, budget);
        assert!(pruned.len() - 1 <= budget, "budget exceeded: {} > {budget}", pruned.len() - 1);
        if t.len() - 1 <= budget {
            // under budget: identity
            assert_eq!(pruned.len(), t.len());
            assert_eq!(kept, (0..t.len()).collect::<Vec<_>>());
        } else {
            // over budget: fully used (cumulative or not, budget many nodes
            // are always reachable greedily because every prefix of a
            // root-path fits)
            assert_eq!(pruned.len() - 1, budget, "budget under-used");
        }
    });
}

#[test]
fn prop_rerank_cumulative_scores_keep_exact_top_k() {
    check("rerank top-k", 150, |rng, _| {
        let t = random_cumulative_tree(rng, 40);
        if t.len() < 3 {
            return;
        }
        let budget = 1 + rng.below(t.len() - 2);
        let (_, kept) = rerank(&t, budget);
        if t.len() - 1 <= budget {
            return;
        }
        // with monotone cumulative scores, selection == plain top-budget
        let mut order: Vec<usize> = (1..t.len()).collect();
        order.sort_by(|&a, &b| {
            t.nodes[b].score.total_cmp(&t.nodes[a].score).then(a.cmp(&b))
        });
        let mut expect: Vec<usize> = order[..budget].to_vec();
        expect.push(0);
        expect.sort_unstable();
        assert_eq!(kept, expect, "cumulative-score rerank must be exact top-k");
    });
}

#[test]
fn prop_uniform_confidence_degrades_to_static_prefix() {
    check("rerank uniform", 50, |rng, _| {
        // Build a static-shaped tree (4/8/8/5 or random widths) in BFS
        // order with UNIFORM per-edge confidence; reranking to any budget
        // must keep exactly the first `budget` nodes in BFS order — i.e.
        // the static tree truncated to the budget.
        let widths: Vec<usize> = if rng.f32() < 0.3 {
            TreeSpec::tree_default().level_widths
        } else {
            (0..1 + rng.below(4)).map(|_| 1 + rng.below(6)).collect()
        };
        let edge_logp = -(rng.f32() + 0.1);
        let mut t = DraftTree::with_root(0);
        let mut prev_level: Vec<usize> = vec![0];
        for &w in &widths {
            let mut level = Vec::new();
            for i in 0..w {
                let parent = prev_level[i % prev_level.len()];
                let score = t.nodes[parent].score + edge_logp;
                level.push(t.add(parent, i as u32, score, None));
            }
            prev_level = level;
        }
        let budget = 1 + rng.below(t.len() + 2);
        let (pruned, kept) = rerank(&t, budget);
        let expect_n = budget.min(t.len() - 1);
        assert_eq!(
            kept,
            (0..=expect_n).collect::<Vec<_>>(),
            "uniform confidence must keep the BFS prefix (static truncation)"
        );
        // and the pruned tree's per-level widths are the truncated static widths
        for (i, &oi) in kept.iter().enumerate() {
            assert_eq!(pruned.nodes[i].depth, t.nodes[oi].depth);
        }
    });
}

#[test]
fn prop_select_frontier_is_top_k_and_sorted() {
    check("frontier", 200, |rng, _| {
        let t = random_tree(rng, 30);
        let cands: Vec<usize> = (0..t.len()).filter(|_| rng.f32() < 0.6).collect();
        let k = 1 + rng.below(8);
        let picked = select_frontier(&t, &cands, k);
        assert!(picked.len() <= k);
        assert_eq!(picked.len(), cands.len().min(k));
        // ascending order, all from the candidate set
        for w in picked.windows(2) {
            assert!(w[0] < w[1]);
        }
        let cand_set: HashSet<usize> = cands.iter().copied().collect();
        let picked_set: HashSet<usize> = picked.iter().copied().collect();
        assert!(picked_set.is_subset(&cand_set));
        // every excluded candidate scores <= the worst picked one
        if let Some(worst) = picked
            .iter()
            .map(|&i| t.nodes[i].score)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
        {
            for &c in &cands {
                if !picked_set.contains(&c) {
                    assert!(t.nodes[c].score <= worst + 1e-6);
                }
            }
        }
    });
}

#[test]
fn prop_width_plan_never_truncates() {
    check("width plan", 200, |rng, _| {
        let fam = WidthFamily::from_available(&[8, 16, 32], 32, |_| true);
        let params = DynTreeParams {
            depth: 1 + rng.below(7),
            frontier_k: 1 + rng.below(8),
            branch: 1 + rng.below(4),
            budget: 1 + rng.below(31),
        };
        let rate = if rng.f32() < 0.5 { None } else { Some((rng.f32(), 0.35)) };
        let (t, clamped) = plan_round_width(&fam, &params, rate);
        assert!(fam.widths().contains(&t), "planned width must be a family member");
        assert!(clamped.budget <= params.budget, "the plan only ever shrinks the budget");
        assert!(clamped.budget + 1 <= t, "planned tree (budget + root) always fits the width");
        assert_eq!(
            (clamped.depth, clamped.frontier_k, clamped.branch),
            (params.depth, params.frontier_k, params.branch),
            "shape params pass through unchanged"
        );
        if let Some((r, low)) = rate {
            if r <= low {
                assert!(
                    clamped.budget <= fam.min() - 1,
                    "collapsed acceptance caps the round at the cheapest width"
                );
            }
        }
    });
}

#[test]
fn prop_verify_inputs_prefix_invariant_across_widths() {
    // Shrinking verify padding must not change what the target sees for
    // the REAL tree rows: tokens, positions, and bias rows of the first
    // n slots are identical at any width >= n. The verified logits for
    // every tree node are therefore width-independent, which makes the
    // width-selected greedy path identical to the fixed-tree_t path.
    check("width invariance", 150, |rng, _| {
        let t = random_tree(rng, 20);
        let n = t.len();
        let s = 64usize;
        let cache_len = 1 + rng.below(8);
        let t1 = n + rng.below(4);
        let t2 = t1 + 1 + rng.below(8);
        let (tok1, pos1, bias1) = t.verify_inputs(t1, cache_len, s);
        let (tok2, pos2, bias2) = t.verify_inputs(t2, cache_len, s);
        assert_eq!(&tok1[..n], &tok2[..n]);
        assert_eq!(&pos1[..n], &pos2[..n]);
        assert_eq!(&bias1[..n * s], &bias2[..n * s], "real rows see identical attention");
    });
}

/// Budget-capped dynamic growth at T>0, mirroring
/// `EagleEngine::grow_tree_dynamic`: children sampled i.i.d. from `q`,
/// candidates truncated to the remaining budget by GENERATION order
/// (value-independent), only the top-scoring frontier stepped further.
fn grow_dynamic_sim(rng: &mut Rng, q: &Rc<Vec<f32>>, params: &DynTreeParams) -> DraftTree {
    let mut tree = DraftTree::with_root(0);
    let mut expandable: Vec<usize> = vec![0];
    for lvl in 0..params.depth {
        let frontier = select_frontier(&tree, &expandable, params.frontier_k);
        let mut cands: Vec<(usize, u32, f32)> = Vec::new();
        for &p in &frontier {
            for _ in 0..params.branch {
                let tok = sample(q, rng);
                let score = tree.nodes[p].score + q[tok].max(1e-20).ln();
                cands.push((p, tok as u32, score));
            }
        }
        let room = params.budget.saturating_sub(tree.len() - 1);
        cands.truncate(room);
        if cands.is_empty() {
            break;
        }
        // the engines retain q as a slab row id; this sim keeps q outside
        // the tree (all children share the one distribution under test)
        let mut new_nodes = Vec::new();
        for (p, tok, score) in cands {
            new_nodes.push(tree.add(p, tok, score, Some(0)));
        }
        if lvl + 1 == params.depth {
            break;
        }
        expandable = select_frontier(&tree, &new_nodes, params.frontier_k);
    }
    tree
}

#[test]
fn prop_dyntree_sampling_preserves_target_distribution() {
    // Empirical law for the T>0 growth path: whatever tree the planner
    // grows (full budget or a width-downshifted one), the FIRST token
    // committed each round is distributed exactly as the target `p` —
    // the SpecInfer rule stays unbiased because the budget cap lands
    // before any candidate value is inspected.
    check("dyntree T>0 law", 6, |rng, case| {
        let n = 2 + rng.below(5);
        let p = random_dist(rng, n);
        let q = Rc::new(random_dist(rng, n));
        // alternate full-budget and width-downshifted (t8-like) rounds
        let params = DynTreeParams {
            depth: 1 + rng.below(4),
            frontier_k: 1 + rng.below(4),
            branch: 1 + rng.below(4),
            budget: if case % 2 == 0 { 31 } else { 7 },
        };
        let trials = 30_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let tree = grow_dynamic_sim(rng, &q, &params);
            let children = tree.children(0);
            if children.is_empty() {
                counts[sample(&p, rng)] += 1;
                continue;
            }
            let toks: Vec<usize> = children.iter().map(|&c| tree.nodes[c].token as usize).collect();
            let qs: Vec<&[f32]> = children.iter().map(|_| q.as_slice()).collect();
            match tree_accept(&p, &qs, &toks, rng) {
                TreeVerdict::AcceptChild(ci) => counts[toks[ci]] += 1,
                TreeVerdict::Residual(t) => counts[t] += 1,
            }
        }
        for i in 0..n {
            let emp = counts[i] as f32 / trials as f32;
            assert!(
                (emp - p[i]).abs() < 0.025,
                "token {i}: emp {emp} vs p {} (budget {})",
                p[i],
                params.budget
            );
        }
    });
}

#[test]
fn prop_width_groups_partition_fit_and_cost() {
    // The scheduler's grouping plan must (a) emit every lane exactly
    // once, (b) never place a lane in a group narrower than its own
    // fitted width (no truncation), (c) respect the max group size, and
    // (d) never cost more under the dispatch model than the FCFS
    // max-width batch it replaces.
    check("width groups", 200, |rng, _| {
        let fam = WidthFamily::from_available(&[8, 16, 32], 32, |_| true);
        let n = 1 + rng.below(24);
        let hints: Vec<usize> = (0..n).map(|_| 2 + rng.below(40)).collect();
        let max_group = 1 + rng.below(8);
        let groups = plan_width_groups(&hints, &fam, max_group);
        let mut seen = vec![false; n];
        for g in &groups {
            assert!(!g.members.is_empty() && g.members.len() <= max_group);
            assert!(fam.widths().contains(&g.width), "group width must be lowered");
            for w in g.members.windows(2) {
                assert!(w[0] < w[1], "FCFS order within a group");
            }
            for &m in &g.members {
                assert!(!seen[m], "lane {m} planned twice");
                seen[m] = true;
                assert!(
                    fam.fit(hints[m].min(fam.max())) <= g.width,
                    "lane {m} (hint {}) truncated by group width {}",
                    hints[m],
                    g.width
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "plan dropped a lane");
        // cost law (unchunked): the planned schedule never exceeds the
        // single FCFS batch at the max fitted width
        let unchunked = plan_width_groups(&hints, &fam, n);
        let planned: usize = unchunked.iter().map(|g| group_cost(g.width, g.members.len())).sum();
        let wmax = hints.iter().map(|&h| fam.fit(h.min(fam.max()))).max().unwrap();
        assert!(
            planned <= group_cost(wmax, n),
            "grouping ({planned}) costlier than FCFS ({})",
            group_cost(wmax, n)
        );
    });
}

#[test]
fn prop_width_grouping_is_lossless_for_greedy_outputs() {
    // A lane's round differs between FCFS max-width batching and its
    // width group ONLY in the verify width its (identical) tree is
    // padded to. Padding rows never change the real rows' tokens,
    // positions, or attention bias, so the verified logits — and hence
    // the greedy acceptance walk — are identical per request.
    check("width grouping lossless", 150, |rng, _| {
        let fam = WidthFamily::from_available(&[8, 16, 32], 32, |_| true);
        let n_lanes = 2 + rng.below(6);
        let trees: Vec<DraftTree> = (0..n_lanes).map(|_| random_tree(rng, 20)).collect();
        let hints: Vec<usize> = trees.iter().map(|t| t.len()).collect();
        let fcfs_t = hints.iter().map(|&h| fam.fit(h)).max().unwrap();
        let s = 96usize;
        let cache_len = 1 + rng.below(8);
        for g in plan_width_groups(&hints, &fam, n_lanes) {
            for &li in &g.members {
                let tree = &trees[li];
                let n = tree.len();
                assert!(n <= g.width, "group width must hold every member tree");
                let (tok_g, pos_g, bias_g) = tree.verify_inputs(g.width, cache_len, s);
                let (tok_f, pos_f, bias_f) = tree.verify_inputs(fcfs_t, cache_len, s);
                assert_eq!(&tok_g[..n], &tok_f[..n]);
                assert_eq!(&pos_g[..n], &pos_f[..n]);
                assert_eq!(&bias_g[..n * s], &bias_f[..n * s], "real rows see the same mask");
            }
        }
    });
}

#[test]
fn prop_controller_stays_within_bounds() {
    check("controller bounds", 100, |rng, _| {
        let cfg = ControllerConfig::default();
        let init = DynTreeParams {
            depth: 1 + rng.below(7),
            frontier_k: 1 + rng.below(8),
            branch: 4,
            budget: 31,
        };
        let mut c = SpecController::new(cfg.clone(), init);
        for _ in 0..50 {
            let attempted = 1 + rng.below(8);
            let accepted = rng.below(attempted + 1);
            c.observe_round(accepted, attempted);
            let p = c.params();
            assert!(p.depth >= cfg.min_depth && p.depth <= cfg.max_depth);
            assert!(p.frontier_k >= cfg.min_frontier && p.frontier_k <= cfg.max_frontier);
            assert_eq!(p.budget, 31, "controller must never change the verify budget");
            assert_eq!(p.branch, 4);
            assert!((0.0..=1.0).contains(&c.rate_ewma));
        }
    });
}
