//! Property tests for the PR-10 draft-source layer: every
//! [`DraftSource`] proposal shape must be lossless under the shared
//! acceptance walks — at T=0 the greedy walk commits exactly the
//! target's argmax chain, and at T>0 the SpecInfer recursive-rejection
//! walk preserves the target distribution whether the q rows are
//! sampled (eagle trees, chain-LM chains) or one-hot (the deterministic
//! n-gram / Medusa proposals) — and the `--draft auto` policy must
//! converge to the score-argmax source. The `count-alloc` module
//! re-asserts the warm-round zero-allocation guarantee through
//! `&mut dyn DraftSource` trait dispatch.

use eagle_serve::eval::bench::sim_sampled_grow;
use eagle_serve::spec::dyntree::SourceSelector;
use eagle_serve::spec::engine::sampled_accept_walk;
use eagle_serve::spec::sampling::argmax;
use eagle_serve::spec::scratch::RoundScratch;
use eagle_serve::spec::source::{
    greedy_accept_walk, push_one_hot_q, sim_accepted_per_round, SourceKind,
};
use eagle_serve::spec::tree::DraftTree;
use eagle_serve::util::prop::{check, random_dist};
use eagle_serve::util::rng::Rng;

/// Logits whose softmax (t=1) reproduces `p` up to float slop.
fn logits_of(p: &[f32]) -> Vec<f32> {
    p.iter().map(|&x| x.max(1e-20).ln()).collect()
}

/// First token a round commits: the first accepted child, or the bonus.
fn first_token(tree: &DraftTree, path: &[usize], bonus: u32) -> usize {
    if path.len() > 1 {
        tree.nodes[path[1]].token as usize
    } else {
        bonus as usize
    }
}

/// Empirical first-committed-token distribution over `trials` rounds,
/// each produced by `build` writing a fresh proposal into the reused
/// tree + scratch (the walk consumes q rows from the scratch slab).
fn first_token_dist(
    n: usize,
    trials: usize,
    tlogits: &[f32],
    rng: &mut Rng,
    mut build: impl FnMut(&mut DraftTree, &mut RoundScratch, &mut Rng),
) -> Vec<f32> {
    let mut s = RoundScratch::new(1, n);
    s.reserve(1, n, 64, 32, 32, 8);
    s.reserve_q(n, 32);
    let mut tree = DraftTree::default();
    let mut counts = vec![0usize; n];
    let mut alpha = [(0u64, 0u64); 5];
    for _ in 0..trials {
        tree.reset(0);
        s.qs.clear(n);
        build(&mut tree, &mut s, rng);
        let bonus = sampled_accept_walk(&tree, |_| tlogits, 1.0, rng, &mut alpha, &mut s);
        counts[first_token(&tree, &s.path, bonus)] += 1;
    }
    counts.iter().map(|&c| c as f32 / trials as f32).collect()
}

fn assert_close(emp: &[f32], p: &[f32], tol: f32, what: &str) {
    for (i, (&e, &t)) in emp.iter().zip(p).enumerate() {
        assert!((e - t).abs() < tol, "{what}: token {i} emp {e} vs p {t}");
    }
}

// ---------------------------------------------------------------------------
// T>0 losslessness per proposal shape

#[test]
fn prop_one_hot_q_chain_preserves_target_distribution() {
    // the n-gram / Medusa shape: a deterministic token chain whose
    // nodes carry one-hot q rows. SpecInfer with a one-hot q degenerates
    // to "accept w.p. p(token), else resample from the residual", so the
    // first committed token must be distributed exactly as the target p
    // NO MATTER which tokens the chain proposes.
    check("one-hot q chain is lossless", 3, |rng, case| {
        let n = 3 + rng.below(3);
        let p = random_dist(rng, n);
        let tlogits = logits_of(&p);
        let gamma = 1 + rng.below(4);
        // fixed adversarial chain for the whole case (e.g. a stale
        // n-gram continuation the target disagrees with)
        let chain: Vec<u32> = (0..gamma).map(|_| rng.below(n) as u32).collect();
        let trials = 30_000;
        let emp = first_token_dist(n, trials, &tlogits, rng, |tree, s, _| {
            let mut parent = 0usize;
            for &tok in &chain {
                let qid = push_one_hot_q(s, n, tok);
                parent = tree.add(parent, tok, 0.0, Some(qid));
            }
        });
        assert_close(&emp, &p, 0.025, &format!("case {case} (one-hot chain)"));
    });
}

#[test]
fn prop_sampled_q_chain_preserves_target_distribution() {
    // the chain-LM shape: each node sampled from the draft distribution
    // q, with q kept for the walk — classic speculative sampling's
    // guarantee, through the same code path ChainLmSource uses.
    check("sampled q chain is lossless", 3, |rng, case| {
        let n = 3 + rng.below(3);
        let p = random_dist(rng, n);
        let q = random_dist(rng, n);
        let tlogits = logits_of(&p);
        let gamma = 1 + rng.below(4);
        let trials = 30_000;
        let emp = first_token_dist(n, trials, &tlogits, rng, |tree, s, rng| {
            let mut parent = 0usize;
            for _ in 0..gamma {
                let qid = s.qs.push(&q) as u32;
                let tok = {
                    // inverse-CDF sample from q on the walk's RNG stream
                    let u = rng.f32();
                    let mut acc = 0.0f32;
                    let mut t = n - 1;
                    for (i, &qi) in q.iter().enumerate() {
                        acc += qi;
                        if u < acc {
                            t = i;
                            break;
                        }
                    }
                    t as u32
                };
                parent = tree.add(parent, tok, 0.0, Some(qid));
            }
        });
        assert_close(&emp, &p, 0.025, &format!("case {case} (sampled chain)"));
    });
}

#[test]
fn prop_eagle_shape_tree_preserves_target_distribution() {
    // the eagle shape: multi-level sampled trees grown by the shared
    // growth sim (per-level i.i.d. draws from q, siblings sharing q
    // rows) — the tree-structured SpecInfer guarantee.
    check("eagle-shape sampled tree is lossless", 2, |rng, case| {
        let n = 3 + rng.below(3);
        let p = random_dist(rng, n);
        let q = random_dist(rng, n);
        let tlogits = logits_of(&p);
        let dlogits = logits_of(&q);
        let levels: Vec<usize> = (0..1 + rng.below(2)).map(|_| 1 + rng.below(3)).collect();
        let trials = 30_000;
        let emp = first_token_dist(n, trials, &tlogits, rng, |tree, s, rng| {
            sim_sampled_grow(tree, s, &dlogits, 1.0, &levels, rng);
        });
        assert_close(&emp, &p, 0.025, &format!("case {case} (eagle tree)"));
    });
}

// ---------------------------------------------------------------------------
// T=0: the greedy walk commits exactly the target's argmax chain

#[test]
fn prop_greedy_walk_commits_exactly_the_argmax_chain() {
    // For ANY proposed tree: every accepted edge's token is the argmax
    // of its parent's verified row, the bonus is the argmax of the
    // deepest accepted node's row, and the walk is maximal (it never
    // stops while an argmax child exists). Together these make greedy
    // speculative decoding bit-identical to vanilla argmax decoding for
    // every source, which is why `--draft` can never change T=0 output.
    check("greedy walk == argmax chain", 40, |rng, case| {
        let n = 4 + rng.below(5);
        let nodes = 2 + rng.below(10);
        let mut tree = DraftTree::with_root(rng.below(n) as u32);
        for _ in 0..nodes {
            let parent = rng.below(tree.len());
            tree.add(parent, rng.below(n) as u32, 0.0, None);
        }
        let rows: Vec<Vec<f32>> = (0..tree.len())
            .map(|_| (0..n).map(|_| rng.f32() * 6.0 - 3.0).collect())
            .collect();
        let mut s = RoundScratch::new(1, n);
        s.reserve(1, n, 64, 32, 32, 8);
        let mut alpha = [(0u64, 0u64); 5];
        let bonus = greedy_accept_walk(&tree, |i| rows[i].as_slice(), &mut alpha, &mut s);
        assert_eq!(s.path[0], 0, "case {case}: walk must start at the root");
        for w in s.path.windows(2) {
            let (parent, child) = (w[0], w[1]);
            assert_eq!(tree.nodes[child].parent, Some(parent), "case {case}: path not a chain");
            assert_eq!(
                tree.nodes[child].token as usize,
                argmax(&rows[parent]),
                "case {case}: accepted a non-argmax token"
            );
        }
        let last = *s.path.last().unwrap();
        let want = argmax(&rows[last]);
        assert_eq!(bonus as usize, want, "case {case}: bonus must be the last argmax");
        let stopped_early = tree
            .children(last)
            .iter()
            .any(|&c| tree.nodes[c].token as usize == want);
        assert!(!stopped_early, "case {case}: walk stopped despite an argmax child");
    });
}

// ---------------------------------------------------------------------------
// policy: auto converges to the score-argmax source

#[test]
fn prop_selector_converges_to_score_argmax() {
    // constant observations make the EWMA exact, so after the probe
    // phase the selector's winner must equal the argmax of
    // sim_accepted_per_round / cost_hint at every repetitiveness
    check("selector winner == score argmax", 25, |rng, case| {
        let r = rng.f32() as f64;
        let sel = SourceSelector::new();
        for _ in 0..100 {
            let k = sel.pick(0.0);
            sel.observe(k, sim_accepted_per_round(k, r));
        }
        let expect = SourceKind::ALL
            .into_iter()
            .max_by(|a, b| {
                let sa = sim_accepted_per_round(*a, r) / a.cost_hint();
                let sb = sim_accepted_per_round(*b, r) / b.cost_hint();
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap();
        assert_eq!(sel.best(0.0), expect, "case {case}: r={r}");
    });
}

// ---------------------------------------------------------------------------
// count-alloc: trait dispatch adds zero warm-round bytes

#[cfg(feature = "count-alloc")]
mod alloc_props {
    use eagle_serve::metrics::GenRecord;
    use eagle_serve::spec::engine::GenConfig;
    use eagle_serve::spec::scratch::RoundScratch;
    use eagle_serve::spec::source::{AdvanceCtx, DraftSource, NgramSource};
    use eagle_serve::spec::tree::DraftTree;
    use eagle_serve::util::count_alloc::thread_allocated_bytes;
    use eagle_serve::util::rng::Rng;

    /// A warm propose/advance round through `&mut dyn DraftSource` must
    /// not touch the allocator: the vtable indirection, the one-hot q
    /// pushes (T>0), and the n-gram re-indexing all run on reserved
    /// buffers — the trait layer inherits the S22 zero-alloc guarantee.
    #[test]
    fn count_alloc_trait_dispatch_round_allocates_nothing_when_warm() {
        let vocab = 64usize;
        let gamma = 5usize;
        let mut ngram = NgramSource::new(gamma, 8, vocab);
        let src: &mut dyn DraftSource = &mut ngram;
        let cfg = GenConfig { max_new: 64, temperature: 1.0, seed: 9, eos: None };
        let mut rec = GenRecord::new(4);
        // repetitive stream: every round retrieves a full gamma chain
        let mut committed: Vec<u32> = Vec::with_capacity(256);
        for i in 0..32u32 {
            committed.push(i % 3 + 1);
        }
        src.begin(&[], 0, 0, &committed, &cfg, &mut rec).unwrap();
        let mut s = RoundScratch::new(1, vocab);
        s.reserve(1, vocab, 64, src.max_nodes(), src.verify_t(), src.max_step_w().max(1));
        s.reserve_q(vocab, src.max_nodes());
        let mut tree = DraftTree::default();
        tree.nodes.reserve(src.max_nodes());
        let mut rng = Rng::new(7);
        let path = [0usize];
        let mut a0 = 0;
        for round in 0..17 {
            if round == 1 {
                a0 = thread_allocated_bytes(); // round 0 was the warm-up
            }
            let m = committed.len() - 1;
            tree.reset(committed[m]);
            src.begin_round(&mut s, vocab);
            src.propose(&mut tree, &mut s, &committed, m, &cfg, &mut rng, &mut rec).unwrap();
            assert_eq!(tree.len(), gamma + 1, "round {round}: retrieval must fill the chain");
            committed.push(committed.len() as u32 % 3 + 1); // the round's commit
            let ctx = AdvanceCtx {
                committed: &committed,
                m_old: m,
                m_new: m + 1,
                path: &path,
                tree: &tree,
                verify_feats: &[],
                verify_t: 8,
            };
            src.advance(&ctx, &mut s, &mut rec).unwrap();
        }
        assert_eq!(
            thread_allocated_bytes() - a0,
            0,
            "warm trait-dispatch rounds touched the allocator"
        );
    }
}
