//! Allocator-level verification of the S22 zero-allocation guarantee
//! (the `count-alloc` feature): the capacity-delta metric
//! (`round_host_alloc_bytes`) only sees buffers the scratch subsystem
//! tracks, so these tests re-assert the guarantee against the REAL
//! allocator — a thread-local counting `GlobalAlloc` registered by the
//! crate under the feature. Host-only round simulations (greedy and
//! sampled) run without artifacts; the full-engine assertions are
//! artifact-gated like the rest of `integration.rs`. Device-call
//! staging (PJRT literal uploads — the device-buffer-residency ROADMAP
//! item) is excluded via a scoped pause inside the model wrappers.
#![cfg(feature = "count-alloc")]

use eagle_serve::coordinator::request::Method;
use eagle_serve::eval::bench::{
    default_bench_tree, sim_round_scratch, sim_sampled_grow, sim_scratch,
};
use eagle_serve::eval::runner::{Runner, RunSpec};
use eagle_serve::eval::Workload;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::spec::dyntree::{DynTreeConfig, TreePolicy};
use eagle_serve::spec::engine::{sampled_accept_walk, GenConfig};
use eagle_serve::spec::scratch::RoundScratch;
use eagle_serve::spec::tree::DraftTree;
use eagle_serve::text::bpe::Bpe;
use eagle_serve::util::count_alloc::thread_allocated_bytes;
use eagle_serve::util::rng::Rng;

#[test]
fn count_alloc_greedy_round_sim_allocates_nothing_when_warm() {
    let tree = default_bench_tree();
    let mut s = sim_scratch();
    let mut acc = sim_round_scratch(&tree, &mut s); // warm-up round
    let a0 = thread_allocated_bytes();
    for _ in 0..8 {
        acc = acc.wrapping_add(sim_round_scratch(&tree, &mut s));
    }
    assert_eq!(
        thread_allocated_bytes() - a0,
        0,
        "warm greedy round sim touched the allocator (checksum {acc})"
    );
}

/// One sampled (T>0) round on the slab path: per-level i.i.d. growth
/// from q (rows in `s.qs`, via the shared [`sim_sampled_grow`] sim)
/// followed by the shared SpecInfer walk — the host side of what both
/// engines run at temperature > 0.
fn sampled_round(
    tree: &mut DraftTree,
    s: &mut RoundScratch,
    dlogits: &[f32],
    tlogits: &[f32],
    rng: &mut Rng,
    alpha: &mut [(u64, u64)],
) -> u32 {
    sim_sampled_grow(tree, s, dlogits, 1.0, &[4, 8, 8, 5], rng);
    sampled_accept_walk(tree, |_| tlogits, 1.0, rng, alpha, s)
}

#[test]
fn count_alloc_sampled_round_sim_allocates_nothing_when_warm() {
    let n = 16;
    let mut s = RoundScratch::new(1, n);
    s.reserve(1, n, 64, 32, 32, 8);
    s.reserve_q(n, 32); // the sampled-path reservation the engines add at T>0
    let mut tree = DraftTree::default();
    let mut rng = Rng::new(3);
    let dlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin()).collect();
    let tlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.67).cos()).collect();
    let mut alpha = [(0u64, 0u64); 5];
    let mut acc = sampled_round(&mut tree, &mut s, &dlogits, &tlogits, &mut rng, &mut alpha);
    let a0 = thread_allocated_bytes();
    for _ in 0..8 {
        acc = acc.wrapping_add(sampled_round(
            &mut tree, &mut s, &dlogits, &tlogits, &mut rng, &mut alpha,
        ));
    }
    assert_eq!(
        thread_allocated_bytes() - a0,
        0,
        "warm sampled (T>0) round sim touched the allocator (checksum {acc})"
    );
}

/// The serving observability record path — flight-recorder ring,
/// registry counters/histograms, and the server's composite
/// [`RoundObserver`] — must stay allocation-free when called from a
/// warm round, for BOTH the greedy and the sampled (T>0) host round
/// sims. This is the host-only form of the engine-level guarantee: the
/// engines emit their round event BEFORE taking the per-round counted
/// delta, so an allocating observer would show up there too.
#[test]
fn count_alloc_observer_and_histogram_record_path_allocates_nothing() {
    use eagle_serve::metrics::registry::{log_buckets, RegistryBuilder};
    use eagle_serve::metrics::trace::{FlightRecorder, RoundEvent, RoundObserver};
    use eagle_serve::server::ServerMetrics;

    // built once up front — after this, recording must be store/fetch-add only
    let mut b = RegistryBuilder::new();
    let hist = b.histogram("t_round_seconds", "round time", &log_buckets(1e-4, 2.0, 12));
    let ctr = b.counter("t_rounds_total", "rounds");
    let reg = b.build();
    let ring = FlightRecorder::new(16); // smaller than the loop: exercises wrap-around
    let server = ServerMetrics::new(16);
    let ev0 = RoundEvent {
        lane: 0,
        round: 0,
        tree_nodes: 25,
        verify_t: 26,
        draft_w: 10,
        accepted: 4,
        draft_ns: 10_000,
        verify_ns: 40_000,
        host_ns: 5_000,
        alloc_bytes: 0,
    };
    let record_round = |i: u32| {
        let ev = RoundEvent { round: i, accepted: (i % 5) + 1, ..ev0 };
        ring.record(&ev);
        reg.inc(ctr);
        reg.observe(hist, (i as f64 + 1.0) * 1e-4);
        server.on_round(&ev); // the server's observer: ring + round histograms
    };

    // greedy sim rounds with the full record path attached
    let tree = default_bench_tree();
    let mut s = sim_scratch();
    let mut acc = sim_round_scratch(&tree, &mut s); // warm-up round
    record_round(0);
    let a0 = thread_allocated_bytes();
    for i in 1..=24 {
        acc = acc.wrapping_add(sim_round_scratch(&tree, &mut s));
        record_round(i);
    }
    assert_eq!(
        thread_allocated_bytes() - a0,
        0,
        "warm greedy round + observer/histogram path touched the allocator (checksum {acc})"
    );

    // sampled (T>0) sim rounds with the same record path attached
    let n = 16;
    let mut s = RoundScratch::new(1, n);
    s.reserve(1, n, 64, 32, 32, 8);
    s.reserve_q(n, 32);
    let mut dtree = DraftTree::default();
    let mut rng = Rng::new(5);
    let dlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin()).collect();
    let tlogits: Vec<f32> = (0..n).map(|i| (i as f32 * 0.67).cos()).collect();
    let mut alpha = [(0u64, 0u64); 5];
    let mut acc = sampled_round(&mut dtree, &mut s, &dlogits, &tlogits, &mut rng, &mut alpha);
    record_round(100);
    let a0 = thread_allocated_bytes();
    for i in 101..=124 {
        acc = acc.wrapping_add(sampled_round(
            &mut dtree, &mut s, &dlogits, &tlogits, &mut rng, &mut alpha,
        ));
        record_round(i);
    }
    assert_eq!(
        thread_allocated_bytes() - a0,
        0,
        "warm sampled round + observer/histogram path touched the allocator (checksum {acc})"
    );
    // the recorders really saw every round
    assert_eq!(ring.recorded(), 50);
    assert_eq!(server.trace.recorded(), 50);
    assert_eq!(reg.hist_count(hist), 50);
    assert_eq!(reg.counter_value(ctr), 50);
}

/// The lane-checkpoint capture/restore cycle (S24 suspend/resume) must
/// be allocation-free once the checkpoint's buffers are reserved: token
/// / root / pending capture, controller snapshot + restore, the lane-KV
/// copy-out shape, and the O(1) `Rng::resume` stream rebuild. This is
/// the allocator-level form of the footprint-invariance property in
/// tests/prop_checkpoint.rs.
#[test]
fn count_alloc_warm_checkpoint_capture_and_restore_allocates_nothing() {
    use eagle_serve::coordinator::LaneCheckpoint;
    use eagle_serve::spec::dyntree::{
        ControllerConfig, ControllerSnapshot, DynTreeParams, SpecController,
    };

    let (max_ctx, d, vocab, accept_a) = (256usize, 64usize, 512usize, 16usize);
    let cfg = ControllerConfig::default();
    let mut ck = LaneCheckpoint::new();
    ck.reserve(max_ctx, d, vocab, accept_a);
    ck.reserve_kv(max_ctx * d, max_ctx * d / 2);
    let mut snap = ControllerSnapshot::default();
    snap.reserve(cfg.max_depth);
    ck.controller = Some(snap);
    let init = DynTreeParams { depth: 3, frontier_k: 4, branch: 4, budget: 31 };
    let mut ctrl = SpecController::new(cfg.clone(), init);
    let mut restored = SpecController::new(cfg, init);

    // lane state staged once up front; the cycle only copies from it
    let committed: Vec<u32> = (0..max_ctx).map(|i| (i % vocab) as u32).collect();
    let feat: Vec<f32> = (0..d).map(|i| i as f32 * 0.5).collect();
    let logits: Vec<f32> = (0..vocab).map(|i| (i as f32 * 0.13).sin()).collect();
    let idx: Vec<i32> = (0..accept_a as i32).collect();
    let kv: Vec<f32> = (0..max_ctx * d).map(|i| i as f32 * 0.25).collect();
    let alpha = [(1u64, 1u64), (1, 1), (0, 1)];
    let mut rng = Rng::new(11);

    let mut cycle = |m: usize| {
        rng.f32(); // the lane consumed draws since the last boundary
        ctrl.observe(&alpha);
        ck.capture_tokens(&committed[..m], m);
        ck.capture_root(&feat, &logits);
        ck.capture_pending(-1, &idx, idx.len() as i32);
        ck.rng_seed = 11;
        ck.rng_draws = rng.draws();
        ctrl.snapshot_into(ck.controller.as_mut().unwrap());
        ck.kv_target.clear();
        ck.kv_target.extend_from_slice(&kv[..m * d]); // lane-KV copy-out shape
        // resume side: splice the state back into a peer controller and
        // rebuild the RNG stream position in O(1)
        restored.restore(ck.controller.as_ref().unwrap());
        let r = Rng::resume(ck.rng_seed, ck.rng_draws);
        assert_eq!(r.draws(), rng.draws());
    };

    cycle(max_ctx); // warm-up: first capture fills the reserved buffers
    let a0 = thread_allocated_bytes();
    for i in 0..8usize {
        cycle(128 + (i * 29) % 128);
    }
    assert_eq!(
        thread_allocated_bytes() - a0,
        0,
        "warm checkpoint capture/restore cycle touched the allocator"
    );
    assert_eq!(restored.params(), ctrl.params(), "restored controller diverged");
    assert_eq!(restored.rounds, ctrl.rounds);
}

// ---- artifact-gated: the whole engines under the counting allocator ----

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn count_alloc_engine_rounds_allocate_nothing_after_warmup_incl_t1() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let runner = Runner::new(&artifacts_dir()).expect("runner");
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap()).expect("vocab");
    let bundle =
        ModelBundle::load(&runner.rt, &runner.man, "toy-s", &["eagle"], false, false).unwrap();
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p).unwrap();
    let p = &wl.prompts[0];
    // full serving observability attached: the engines emit each round
    // event BEFORE taking the per-round counted delta, so the recorder
    // + histogram cost is covered by the zero-alloc assertions below
    let sm = eagle_serve::server::ServerMetrics::new(128);
    // bs=1: static + dynamic trees, greedy + sampled
    for temperature in [0.0f32, 1.0] {
        let cfg = GenConfig { max_new: 32, temperature, seed: 3, eos: None };
        for tree in [
            TreePolicy::default_tree(),
            TreePolicy::Dynamic(DynTreeConfig::default()),
        ] {
            let spec = RunSpec {
                method: Method::Eagle,
                temperature,
                tree: tree.clone(),
                ..Default::default()
            };
            let rec = runner.run_one_observed(&bundle, &p.ids, &spec, &cfg, Some(&sm)).unwrap();
            assert!(
                !rec.round_alloc_counted_bytes.is_empty(),
                "allocator metric must be recorded"
            );
            assert_eq!(
                rec.counted_steady_alloc_bytes(),
                0,
                "T={temperature} {} tree: steady rounds allocated: {:?}",
                tree.name(),
                rec.round_alloc_counted_bytes
            );
        }
    }
    assert!(sm.trace.recorded() > 0, "observed bs=1 runs must land in the flight recorder");
    // batched lock-step: greedy + sampled lanes on one engine, observer
    // attached the way the server attaches it
    let prompts: Vec<Vec<u32>> = wl.prompts.iter().take(2).map(|pr| pr.ids.clone()).collect();
    let be = eagle_serve::coordinator::BatchEagleEngine::new(
        &bundle.target, &bundle.drafts["eagle"], &runner.man.constants,
    )
    .with_observer(&sm);
    let before_batched = sm.trace.recorded();
    for temperature in [0.0f32, 1.0] {
        let cfg = GenConfig { max_new: 20, temperature, seed: 7, eos: None };
        for rec in be.generate(&prompts, &cfg).unwrap() {
            assert_eq!(
                rec.counted_steady_alloc_bytes(),
                0,
                "batched T={temperature}: steady rounds allocated: {:?}",
                rec.round_alloc_counted_bytes
            );
        }
    }
    assert!(
        sm.trace.recorded() > before_batched,
        "observed batched runs must land in the flight recorder"
    );
}
