//! MoE demo (paper Table 3): EAGLE on the Mixtral-analog toy-moe target —
//! speculative sampling accelerates MoE less than dense models.
//!
//!   cargo run --release --example moe_demo

use eagle_serve::coordinator::request::Method;
use eagle_serve::eval::runner::{speedup, RunSpec, Runner};
use eagle_serve::eval::Workload;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::text::bpe::Bpe;

fn main() -> anyhow::Result<()> {
    let runner = Runner::new(&artifacts_dir())?;
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())?;
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p)?;
    let prompts = wl.take(8);

    for model in ["toy-s", "toy-moe"] {
        let bundle = ModelBundle::load(&runner.rt, &runner.man, model, &["eagle"], false, false)?;
        let vanilla = RunSpec { method: Method::Vanilla, ..Default::default() };
        let base = runner.run_with(&bundle, &prompts, &vanilla)?;
        let eagle = runner.run_with(&bundle, &prompts, &RunSpec::default())?;
        println!(
            "{model:8} ({}): vanilla {:6.1} tok/s  eagle {:6.1} tok/s  speedup {:.2}x  tau {:.2}",
            if bundle.target.is_moe { "4-expert top-2 MoE" } else { "dense" },
            base.tokens_per_sec(),
            eagle.tokens_per_sec(),
            speedup(&eagle, &base),
            eagle.tau(),
        );
    }
    println!("\nExpected shape (paper Tab. 3): the MoE target accelerates less than dense.");
    Ok(())
}
