//! End-to-end serving driver (the EXPERIMENTS.md validation run): starts
//! the HTTP server on a background thread, fires a batch of concurrent
//! client requests over real sockets, and reports latency percentiles +
//! throughput per method. Proves all layers compose: HTTP -> queue ->
//! scheduler -> EAGLE engine -> PJRT executables (L2 graphs + L1 kernel).
//!
//!   cargo run --release --example serving_demo

use eagle_serve::server::http::{get, post_json};
use eagle_serve::util::json::Json;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:8191";
    std::thread::spawn(move || {
        let cfg = eagle_serve::server::ServeConfig::new(
            addr,
            "toy-s",
            &eagle_serve::models::artifacts_dir(),
        );
        eagle_serve::server::serve(cfg).expect("server failed");
    });
    // wait for readiness
    for _ in 0..600 {
        if get(addr, "/healthz").map(|(c, _)| c == 200).unwrap_or(false) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("server ready at {addr}");
    // warmup: the inference worker compiles executables lazily at startup;
    // don't charge that to the first timed batch
    let _ = post_json(
        addr,
        "/v1/generate",
        r#"{"prompt":"warmup","max_tokens":4,"method":"vanilla"}"#,
    )?;

    let prompts = [
        "write two sentences about the quiet river.",
        "tom has 9 apples. tom buys 3 more and gives away 2. how many apples remain?",
        "write a function f3 that maps x to x + 2 and apply it to range 4.",
        "state the density of iron.",
        "record: name anna; age 31; city harbor. extract the age of anna.",
        "what did the poet write in 1850?",
    ];

    for method in ["vanilla", "eagle"] {
        let t0 = Instant::now();
        let mut lat = Vec::new();
        let mut toks = 0usize;
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| {
                let body = Json::obj(vec![
                    ("prompt", Json::Str(p.to_string())),
                    ("max_tokens", Json::Num(32.0)),
                    ("method", Json::Str(method.to_string())),
                ])
                .to_string();
                std::thread::spawn(move || post_json(addr, "/v1/generate", &body))
            })
            .collect();
        for h in handles {
            let (code, body) = h.join().unwrap()?;
            anyhow::ensure!(code == 200, "request failed: {code} {body}");
            let v = Json::parse(&body)?;
            lat.push(v.req("latency_ms")?.as_f64().unwrap_or(0.0));
            toks += v.req("tokens")?.as_usize().unwrap_or(0);
        }
        let wall = t0.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{method:8} {} reqs  {toks:4} tokens  wall {wall:5.2}s  throughput {:6.1} tok/s  p50 {:6.1} ms  p99 {:6.1} ms",
            prompts.len(),
            toks as f64 / wall,
            lat[lat.len() / 2],
            lat[lat.len() - 1],
        );
    }
    let (_, metrics) = get(addr, "/metrics")?;
    println!("\n/metrics:\n{metrics}");
    Ok(())
}
