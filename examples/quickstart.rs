//! Quickstart: load artifacts, generate with EAGLE, compare to vanilla.
//!
//!   make artifacts && cargo run --release --example quickstart

use eagle_serve::prelude::*;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let man = Manifest::load(&artifacts_dir())?;
    let bpe = Bpe::load(man.path(&man.tokenizer).to_str().unwrap())?;
    let bundle = ModelBundle::load(&rt, &man, "toy-s", &["eagle"], false, false)?;

    let prompt = "tom has 12 apples. tom buys 5 more and gives away 3. how many apples remain?";
    let ids = bpe.encode_prompt(prompt);
    let cfg = GenConfig { max_new: 48, temperature: 0.0, seed: 7, eos: Some(bpe.eos()) };

    // vanilla auto-regressive decoding: one target pass per token
    let vanilla = VanillaEngine::new(&bundle.target).generate(&ids, &cfg)?;

    // EAGLE: feature-level tree drafting + one verification pass per ~4 tokens
    let draft = &bundle.drafts["eagle"];
    let eagle = EagleEngine::new_tree(&bundle.target, draft, &man.constants).generate(&ids, &cfg)?;

    println!("prompt  : {prompt}");
    println!("output  : {}", bpe.decode(&eagle.tokens).trim());
    println!();
    println!(
        "vanilla : {:6.1} ms  {:5.1} tok/s  {} target passes",
        vanilla.wall_ns as f64 / 1e6,
        vanilla.tokens_per_sec(),
        vanilla.target_passes
    );
    println!(
        "eagle   : {:6.1} ms  {:5.1} tok/s  {} target passes  tau {:.2}",
        eagle.wall_ns as f64 / 1e6,
        eagle.tokens_per_sec(),
        eagle.target_passes,
        eagle.tau()
    );
    println!(
        "speedup : {:.2}x   lossless: {}",
        eagle.tokens_per_sec() / vanilla.tokens_per_sec(),
        if vanilla.tokens == eagle.tokens { "yes (greedy outputs identical)" } else { "NO — BUG" }
    );
    Ok(())
}
