//! Ablation tour (paper §3 + §5.3.2 live): walks the two observations
//! EAGLE is built on, on real artifacts —
//!   1. feature-level drafting beats token-level drafting;
//!   2. the shifted token resolves sampling uncertainty.
//!
//!   cargo run --release --example ablation_tour

use eagle_serve::coordinator::request::Method;
use eagle_serve::eval::runner::{speedup, RunSpec, Runner};
use eagle_serve::eval::Workload;
use eagle_serve::models::{artifacts_dir, ModelBundle};
use eagle_serve::text::bpe::Bpe;

fn main() -> anyhow::Result<()> {
    let runner = Runner::new(&artifacts_dir())?;
    let bpe = Bpe::load(runner.man.path(&runner.man.tokenizer).to_str().unwrap())?;
    let wl = Workload::load(&runner.man, &bpe, "mtbench", runner.man.constants.prefill_p)?;
    let prompts = wl.take(8);
    let bundle = ModelBundle::load(
        &runner.rt,
        &runner.man,
        "toy-s",
        &["eagle", "unshift", "feat", "tok"],
        false,
        false,
    )?;

    let vanilla = RunSpec { method: Method::Vanilla, ..Default::default() };
    let base = runner.run_with(&bundle, &prompts, &vanilla)?;
    println!("vanilla baseline: {:.1} tok/s\n", base.tokens_per_sec());

    println!("-- observation 1: features are easier to autoregress than tokens --");
    for (label, variant) in [("token-AR draft ", "tok"), ("feature-AR draft", "feat")] {
        let spec =
            RunSpec { method: Method::EagleChain, variant: variant.into(), ..Default::default() };
        let agg = runner.run_with(&bundle, &prompts, &spec)?;
        println!(
            "  {label}: speedup {:.2}x  tau {:.2}  0-alpha {}",
            speedup(&agg, &base),
            agg.tau(),
            agg.alphas()[0].map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        );
    }

    println!("\n-- observation 2: the shifted token resolves sampling uncertainty --");
    for (label, variant) in [
        ("feature only              ", "feat"),
        ("feature + unshifted token ", "unshift"),
        ("feature + shifted (EAGLE) ", "eagle"),
    ] {
        let spec =
            RunSpec { method: Method::EagleChain, variant: variant.into(), ..Default::default() };
        let agg = runner.run_with(&bundle, &prompts, &spec)?;
        println!(
            "  {label}: speedup {:.2}x  tau {:.2}  1-alpha {}",
            speedup(&agg, &base),
            agg.tau(),
            agg.alphas()[1].map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        );
    }

    println!("\n-- and the full method: tree drafting on top --");
    let tree = runner.run_with(&bundle, &prompts, &RunSpec::default())?;
    println!("  EAGLE (tree): speedup {:.2}x  tau {:.2}", speedup(&tree, &base), tree.tau());
    Ok(())
}
