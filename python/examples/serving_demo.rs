fn main() {}
