fn main() {}
