fn main() {}
