fn main() {}
