"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/dtypes/mask patterns; assert_allclose against
ref.py as mandated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import tree_attention

NEG = -1e30


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _run(b, t, h, dh, s, mask_p, dtype, seed, block_s=96):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = _rand(ks[0], (b, t, h, dh), dtype)
    k = _rand(ks[1], (b, s, h, dh), dtype)
    v = _rand(ks[2], (b, s, h, dh), dtype)
    keep = jax.random.bernoulli(ks[3], mask_p, (b, t, s))
    # guarantee at least one visible column per row (self-attention invariant)
    keep = keep.at[:, :, 0].set(True)
    bias = jnp.where(keep, 0.0, NEG).astype(jnp.float32)
    out = tree_attention(q, k, v, bias, block_s=block_s)
    ref = tree_attention_ref(q, k, v, bias)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(1, 16),
    h=st.integers(1, 3),
    dh=st.sampled_from([16, 32, 64]),
    s_tiles=st.integers(1, 3),
    mask_p=st.floats(0.2, 1.0),
    seed=st.integers(0, 10_000),
)
def test_kernel_matches_ref_f32(b, t, h, dh, s_tiles, mask_p, seed):
    _run(b, t, h, dh, s_tiles * 96, mask_p, jnp.float32, seed)


@settings(max_examples=8, deadline=None)
@given(
    t=st.integers(1, 8),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 10_000),
)
def test_kernel_matches_ref_bf16(t, dh, seed):
    _run(1, t, 2, dh, 96, 0.7, jnp.bfloat16, seed)


def test_non_multiple_s_falls_back_to_single_tile():
    # S not a multiple of block_s: kernel must still be exact
    _run(1, 4, 2, 32, 100, 0.8, jnp.float32, 0)


def test_fully_masked_rows_do_not_nan():
    q = jnp.ones((1, 2, 1, 16))
    k = jnp.ones((1, 96, 1, 16))
    v = jnp.ones((1, 96, 1, 16))
    bias = jnp.full((1, 2, 96), NEG)
    out = tree_attention(q, k, v, bias)
    assert not bool(jnp.any(jnp.isnan(out)))


def test_tree_mask_semantics_chain_equals_causal():
    """A chain tree (each node attends its ancestors) must equal plain
    causal attention over the same tokens."""
    key = jax.random.PRNGKey(7)
    t, s = 8, 96
    q = jax.random.normal(key, (1, t, 2, 32))
    k = jnp.zeros((1, s, 2, 32)).at[:, :t].set(jax.random.normal(jax.random.PRNGKey(8), (1, t, 2, 32)))
    v = jnp.zeros((1, s, 2, 32)).at[:, :t].set(jax.random.normal(jax.random.PRNGKey(9), (1, t, 2, 32)))
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(s)[None, :]
    bias = jnp.where((cols <= rows) & (cols < t), 0.0, NEG)[None].astype(jnp.float32)
    out = tree_attention(q, k, v, bias)
    ref = tree_attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("block_s", [32, 48, 96, 192])
def test_block_size_invariance(block_s):
    """Flash tiling must be numerically independent of the tile size."""
    _run(1, 6, 2, 32, 192, 0.6, jnp.float32, 3, block_s=block_s)
