"""L2 model invariants: cache-forward vs train-forward equivalence, prefix
invariance, tree == chain equivalence, MoE shapes."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = replace(M.toy_s(), vocab=101, d=64, n_layers=2, n_heads=2, head_dim=32, ffn=96, max_len=48, attn_impl="ref")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def _causal_bias(t, s=None):
    s = s or t
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(s)[None, None, :]
    return jnp.where((cols <= rows), 0.0, M.NEG).astype(jnp.float32)


def _prefill(params, toks, length):
    b, p = toks.shape
    cache = M.init_cache(CFG, b)
    pos = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p)).astype(jnp.int32)
    bias = M.prefill_bias(CFG, p, jnp.full((b,), length, jnp.int32), b)
    return M.forward(params, CFG, toks, pos, pos, bias, cache)


def test_train_forward_matches_cache_forward(params):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, CFG.vocab)
    lg_t, ft_t, _, _, _ = M.forward(
        params, CFG, toks,
        jnp.broadcast_to(jnp.arange(12)[None], (2, 12)), None, _causal_bias(12), None,
    )
    lg_c, ft_c, _, _, _ = _prefill(params, toks, 12)
    np.testing.assert_allclose(np.asarray(lg_t), np.asarray(lg_c), atol=1e-4)
    np.testing.assert_allclose(np.asarray(ft_t), np.asarray(ft_c), atol=1e-5)


def test_prefix_invariance(params):
    """Logits at position i must not depend on tokens after i (causality)."""
    t1 = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, CFG.vocab)
    t2 = t1.at[0, 7:].set((t1[0, 7:] + 1) % CFG.vocab)
    lg1, _, _, _, _ = _prefill(params, t1, 10)
    lg2, _, _, _, _ = _prefill(params, t2, 10)
    np.testing.assert_allclose(np.asarray(lg1[0, :7]), np.asarray(lg2[0, :7]), atol=1e-4)


def test_decode_steps_match_prefill(params):
    """Prefill(k+n) == prefill(k) + n single-token decode steps."""
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, CFG.vocab)
    lg_full, ft_full, _, _, _ = _prefill(params, toks, 12)

    lg_p, ft_p, cache, _, _ = _prefill(params, toks[:, :8].at[:, 8:].get() if False else toks.at[:, 8:].set(0), 8)
    # note: padded prompt columns are masked by length=8, values don't matter
    for i in range(8, 12):
        cl = jnp.array([i], jnp.int32)
        pos = cl[:, None]
        cols = jnp.arange(CFG.max_len)[None, None, :]
        bias = jnp.where(cols <= cl[:, None, None], 0.0, M.NEG).astype(jnp.float32)
        lg_d, ft_d, cache, _, _ = M.forward(
            params, CFG, toks[:, i : i + 1], pos, pos, bias, cache
        )
        np.testing.assert_allclose(
            np.asarray(lg_d[0, 0]), np.asarray(lg_full[0, i]), atol=1e-4,
            err_msg=f"decode step {i}",
        )


def test_tree_verify_chain_path_matches_decode(params):
    """A chain-shaped tree (path) verified in one call must reproduce the
    same logits as sequential decode: the tree-attention correctness core."""
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, CFG.vocab)
    tree_toks = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, CFG.vocab)

    _, _, cache, _, _ = _prefill(params, toks, 8)
    # chain tree: node i attends nodes 0..i
    t = 4
    cl = jnp.array([8], jnp.int32)
    write_pos = cl[:, None] + jnp.arange(t)[None, :]
    pos = write_pos
    cols = jnp.arange(CFG.max_len)[None, None, :]
    rel = cols - cl[:, None, None]
    rows = jnp.arange(t)[None, :, None]
    ok = (cols < cl[:, None, None]) | ((rel >= 0) & (rel <= rows))
    bias = jnp.where(ok, 0.0, M.NEG).astype(jnp.float32)
    lg_tree, ft_tree, _, tk, tv = M.forward(params, CFG, tree_toks, pos, write_pos, bias, cache)

    # sequential decodes of the same tokens
    _, _, cache2, _, _ = _prefill(params, toks, 8)
    for i in range(t):
        cl2 = jnp.array([8 + i], jnp.int32)
        pos2 = cl2[:, None]
        bias2 = jnp.where(cols <= cl2[:, None, None], 0.0, M.NEG).astype(jnp.float32)
        lg_d, _, cache2, _, _ = M.forward(
            params, CFG, tree_toks[:, i : i + 1], pos2, pos2, bias2, cache2
        )
        np.testing.assert_allclose(
            np.asarray(lg_d[0, 0]), np.asarray(lg_tree[0, i]), atol=1e-4,
            err_msg=f"tree node {i}",
        )
    assert tk.shape == (CFG.n_layers, 1, t, CFG.n_heads, CFG.head_dim)


def test_commit_then_decode_matches_plain_decode(params):
    """Verify+commit of an accepted path must leave the cache identical (in
    effect) to having decoded those tokens directly."""
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 8), 0, CFG.vocab)
    tree_toks = jax.random.randint(jax.random.PRNGKey(7), (1, 4), 0, CFG.vocab)
    t = 4

    _, _, cache, _, _ = _prefill(params, toks, 8)
    cl = jnp.array([8], jnp.int32)
    write_pos = cl[:, None] + jnp.arange(t)[None, :]
    cols = jnp.arange(CFG.max_len)[None, None, :]
    rel = cols - cl[:, None, None]
    rows = jnp.arange(t)[None, :, None]
    ok = (cols < cl[:, None, None]) | ((rel >= 0) & (rel <= rows))
    bias = jnp.where(ok, 0.0, M.NEG).astype(jnp.float32)
    _, _, cache_v, tk, tv = M.forward(params, CFG, tree_toks, write_pos, write_pos, bias, cache)
    # accept first 2 nodes (chain prefix)
    cache_c = M.commit(
        CFG, cache_v, cl, tk, tv,
        jnp.array([[0, 1, 0, 0]], jnp.int32), jnp.array([2], jnp.int32),
    )
    # now decode one more token on top; compare against the plain path
    nxt = jnp.array([[5]], jnp.int32)
    cl2 = jnp.array([10], jnp.int32)
    bias2 = jnp.where(cols <= cl2[:, None, None], 0.0, M.NEG).astype(jnp.float32)
    lg_a, _, _, _, _ = M.forward(params, CFG, nxt, cl2[:, None], cl2[:, None], bias2, cache_c)

    _, _, cache_p, _, _ = _prefill(params, toks, 8)
    for i in range(2):
        cli = jnp.array([8 + i], jnp.int32)
        biasi = jnp.where(cols <= cli[:, None, None], 0.0, M.NEG).astype(jnp.float32)
        _, _, cache_p, _, _ = M.forward(
            params, CFG, tree_toks[:, i : i + 1], cli[:, None], cli[:, None], biasi, cache_p
        )
    lg_b, _, _, _, _ = M.forward(params, CFG, nxt, cl2[:, None], cl2[:, None], bias2, cache_p)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), atol=1e-4)


def test_moe_forward_shapes_and_finite():
    cfg = replace(CFG, n_experts=4, top_k=2, ffn=32)
    params = M.init_params(cfg, jax.random.PRNGKey(8))
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 6), 0, cfg.vocab)
    lg, ft, _, _, _ = M.forward(
        params, cfg, toks,
        jnp.broadcast_to(jnp.arange(6)[None], (2, 6)), None, _causal_bias(6), None,
    )
    assert lg.shape == (2, 6, cfg.vocab) and ft.shape == (2, 6, cfg.d)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_pallas_and_ref_model_agree(params):
    """Whole-model equivalence of the two attention implementations."""
    toks = jax.random.randint(jax.random.PRNGKey(10), (1, 8), 0, CFG.vocab)
    lg_ref, _, _, _, _ = _prefill(params, toks, 8)
    cfg_p = replace(CFG, attn_impl="pallas")
    b, p = toks.shape
    cache = M.init_cache(cfg_p, b)
    pos = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p)).astype(jnp.int32)
    bias = M.prefill_bias(cfg_p, p, jnp.full((b,), 8, jnp.int32), b)
    lg_pal, _, _, _, _ = M.forward(params, cfg_p, toks, pos, pos, bias, cache)
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_pal), atol=1e-4)
