"""Tokenizer: roundtrip, determinism, json persistence, and the fixture
dump the rust test suite replays (bit-exact cross-language contract)."""

import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from compile import data
from compile.tokenizer import Bpe, split_words, train_bpe

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures", "tokenizer_cases.json")


@pytest.fixture(scope="module")
def bpe():
    ds = data.gen_dialogues(300, 1)
    return train_bpe(data.corpus_text(ds), 300)


def test_split_words_examples():
    assert split_words("a b") == ["a", " b"]
    assert split_words(" a") == [" a"]
    assert split_words("a  b") == ["a", " ", " b"]
    assert split_words("") == []
    assert split_words("  ") == [" ", " "]
    assert split_words("ab\ncd") == ["ab\ncd"]


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet=st.characters(codec="ascii"), max_size=80))
def test_split_words_partition(s):
    assert "".join(split_words(s)) == s


@settings(max_examples=50, deadline=None)
@given(st.text(max_size=60))
def test_roundtrip(bpe, s):
    assert bpe.decode(bpe.encode(s)) == s


def test_determinism():
    ds = data.gen_dialogues(100, 5)
    t1 = train_bpe(data.corpus_text(ds), 100)
    t2 = train_bpe(data.corpus_text(ds), 100)
    assert t1.merges == t2.merges


def test_json_roundtrip(bpe):
    b2 = Bpe.from_json(bpe.to_json())
    s = "tom has 12 apples. def f3(x):\n    return x * 2"
    assert b2.encode(s) == bpe.encode(s)


def test_specials(bpe):
    ids = bpe.encode_dialogue("hello", "world")
    assert ids[0] == bpe.special_ids["<bos>"]
    assert ids[1] == bpe.special_ids["<user>"]
    assert ids[-1] == bpe.special_ids["<eos>"]
    assert all(0 <= t < bpe.vocab_size for t in ids)


def test_dump_rust_fixtures(bpe):
    """Write (text, ids) cases + the vocab used, for rust's bpe tests."""
    cases = [
        "tom has 12 apples.",
        "def f7(x):\n    return x + 3",
        "the quiet river follows the ancient harbor.",
        "  leading spaces",
        "unicode: café → ok",
        "",
    ]
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(
            {"vocab": json.loads(bpe.to_json()), "cases": [{"text": c, "ids": bpe.encode(c)} for c in cases]},
            f,
        )
    assert os.path.exists(FIXTURE)
