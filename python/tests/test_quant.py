"""int8 weight-only quantization: error bounds and name scheme."""

import jax.numpy as jnp
import numpy as np

from compile.quant import dequant_tree, quantize_leaf, quantize_params


def test_quantize_leaf_roundtrip_error():
    w = np.random.default_rng(0).normal(size=(128, 96)).astype(np.float32)
    out = quantize_leaf("w", w)
    assert [n for n, _ in out] == ["w.q", "w.scale"]
    q, scale = out[0][1], out[1][1]
    deq = q.astype(np.float32) * scale
    # per-channel int8: max error <= scale/2 per column
    assert np.max(np.abs(deq - w) / scale) <= 0.5 + 1e-5


def test_small_and_1d_leaves_passthrough():
    v = np.zeros((16,), np.float32)
    assert quantize_leaf("ln", v) == [("ln", v)]
    small = np.zeros((8, 8), np.float32)
    out = quantize_leaf("tiny", small)
    assert out[0][0] == "tiny"


def test_dequant_tree_inverse_names():
    flat = [("a.w", np.random.rand(128, 128).astype(np.float32)), ("a.ln", np.ones(4, np.float32))]
    qflat = quantize_params(flat)
    deq = dequant_tree([(n, jnp.asarray(a)) for n, a in qflat])
    assert [n for n, _ in deq] == ["a.w", "a.ln"]
    np.testing.assert_allclose(
        np.asarray(deq[0][1]), flat[0][1], atol=float(np.abs(flat[0][1]).max() / 100)
    )
