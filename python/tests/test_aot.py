"""AOT artifact consistency (runs against a built artifacts/ dir; skipped
when absent) + HLO cost audit on a freshly lowered decode graph."""

import json
import os
from dataclasses import replace

import jax
import pytest

from compile import aot, model as M

ART = os.environ.get("EAGLE_ARTIFACTS", os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")), reason="artifacts not built"
)


@needs_artifacts
def test_manifest_files_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    for mname, entry in man["models"].items():
        assert os.path.exists(os.path.join(ART, entry["weights"])), mname
        for ename, e in entry["executables"].items():
            assert os.path.exists(os.path.join(ART, e["hlo"])), f"{mname}.{ename}"
        for dname, d in entry.get("drafts", {}).items():
            assert os.path.exists(os.path.join(ART, d["weights"]))
            for ename, e in d["executables"].items():
                assert os.path.exists(os.path.join(ART, e["hlo"])), f"{mname}.{dname}.{ename}"


@needs_artifacts
def test_manifest_constants_sane():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    c = man["constants"]
    assert c["accept_a"] <= c["tree_t"]
    assert c["draft_w"] <= c["tree_t"]
    widths = c.get("verify_widths", [c["tree_t"]])
    assert c["tree_t"] in widths, "width family must contain the max width"
    assert all(2 <= t <= c["tree_t"] for t in widths)
    assert widths == sorted(widths)
    dwidths = c.get("draft_widths", [c["draft_w"]])
    assert c["draft_w"] in dwidths, "draft family must contain the max step width"
    assert all(1 <= w <= c["draft_w"] for w in dwidths)
    assert dwidths == sorted(dwidths)
    for entry in man["models"].values():
        cfg = entry["config"]
        # tree region + scratch must fit the cache
        assert c["prefill_p"] + c["tree_t"] < cfg["max_len"]


@needs_artifacts
def test_weights_match_param_names():
    from compile.tensorfile import read_stensor

    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname, entry in man["models"].items():
        flat = read_stensor(os.path.join(ART, entry["weights"]))
        assert [n for n, _ in flat] == entry["param_names"], mname


def test_decode_hlo_has_no_duplicate_lm_head_matmul():
    """L2 perf audit: logits and features must come from ONE forward —
    exactly one dot against the LM head in the decode graph."""
    cfg = replace(M.toy_s(), vocab=101, d=64, n_layers=2, n_heads=2, head_dim=32, ffn=96, max_len=48, attn_impl="ref")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tl = aot.TargetLowering(cfg, params)
    fn, ex = tl.decode(1)
    txt = aot.to_hlo_text(jax.jit(fn).lower(*ex))
    # dots with the lm_head shape [d, vocab] appear exactly once
    assert txt.count("f32[64,101]") >= 1
    # per layer: wq/wk/wv/wo + w1/w2/w3 + QK^T + PV = 9 dots, + 1 lm_head.
    # A duplicated feature/logits computation would roughly double this.
    assert txt.count("dot(") <= 10 * cfg.n_layers + 2, "unexpected dot count (duplicated compute?)"


def test_hlo_text_parses_back():
    """The text we emit must round-trip through the HLO parser (what the
    rust loader does)."""
    from jax._src.lib import xla_client as xc

    cfg = replace(M.toy_s(), vocab=101, d=64, n_layers=1, n_heads=1, head_dim=32, ffn=64, max_len=48, attn_impl="ref")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tl = aot.TargetLowering(cfg, params)
    fn, ex = tl.decode(1)
    txt = aot.to_hlo_text(jax.jit(fn).lower(*ex))
    assert "ENTRY" in txt and "f32[" in txt
