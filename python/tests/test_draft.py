"""Draft-head semantics: variant input assembly, shifted-token contract,
medusa shapes, draft-prefill/step equivalence."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import draft as D
from compile import model as M

CFG = replace(M.toy_s(), vocab=97, d=64, n_layers=2, n_heads=2, head_dim=32, ffn=96, max_len=48, attn_impl="ref")


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    heads = {
        v: D.init_draft_params(D.DraftConfig(variant=v, ffn=CFG.ffn), CFG, jax.random.PRNGKey(1))
        for v in D.VARIANTS
    }
    return params, heads


def _causal_bias(t, s):
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(s)[None, None, :]
    return jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)


def test_variant_input_dims(setup):
    _, heads = setup
    assert heads["eagle"]["fc"].shape == (2 * CFG.d, CFG.d)
    assert heads["unshift"]["fc"].shape == (2 * CFG.d, CFG.d)
    assert heads["feat"]["fc"].shape == (CFG.d, CFG.d)
    assert heads["tok"]["fc"].shape == (CFG.d, CFG.d)


def test_feat_variant_ignores_tokens(setup):
    params, heads = setup
    t = 6
    feats = jax.random.normal(jax.random.PRNGKey(2), (1, t, CFG.d))
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, t), 0, CFG.vocab)
    t2 = (t1 + 3) % CFG.vocab
    pos = jnp.arange(t)[None, :]
    bias = _causal_bias(t, t)
    args = (heads["feat"], D.DraftConfig(variant="feat", ffn=CFG.ffn), CFG, params["tok_emb"], params["lm_head"])
    f1, _, _ = D.draft_forward(*args, feats, t1, pos, None, bias, None)
    f2, _, _ = D.draft_forward(*args, feats, t2, pos, None, bias, None)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2))


def test_tok_variant_ignores_features(setup):
    params, heads = setup
    t = 6
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, t), 0, CFG.vocab)
    f1 = jax.random.normal(jax.random.PRNGKey(5), (1, t, CFG.d))
    f2 = f1 + 1.0
    pos = jnp.arange(t)[None, :]
    bias = _causal_bias(t, t)
    args = (heads["tok"], D.DraftConfig(variant="tok", ffn=CFG.ffn), CFG, params["tok_emb"], params["lm_head"])
    o1, _, _ = D.draft_forward(*args, f1, toks, pos, None, bias, None)
    o2, _, _ = D.draft_forward(*args, f2, toks, pos, None, bias, None)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))


def test_eagle_depends_on_both(setup):
    params, heads = setup
    t = 6
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, t), 0, CFG.vocab)
    feats = jax.random.normal(jax.random.PRNGKey(7), (1, t, CFG.d))
    pos = jnp.arange(t)[None, :]
    bias = _causal_bias(t, t)
    args = (heads["eagle"], D.DraftConfig(variant="eagle", ffn=CFG.ffn), CFG, params["tok_emb"], params["lm_head"])
    o, _, _ = D.draft_forward(*args, feats, toks, pos, None, bias, None)
    o_t, _, _ = D.draft_forward(*args, feats, (toks + 1) % CFG.vocab, pos, None, bias, None)
    o_f, _, _ = D.draft_forward(*args, feats + 1.0, toks, pos, None, bias, None)
    assert float(jnp.max(jnp.abs(o - o_t))) > 1e-6
    assert float(jnp.max(jnp.abs(o - o_f))) > 1e-6


def test_draft_cache_step_matches_full_forward(setup):
    """Chain-stepping the head against its KV cache must equal one full
    causal pass over the same inputs (the serving-path contract)."""
    params, heads = setup
    dcfg = D.DraftConfig(variant="eagle", ffn=CFG.ffn)
    t = 8
    feats = jax.random.normal(jax.random.PRNGKey(8), (1, t, CFG.d))
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, t), 0, CFG.vocab)
    pos = jnp.arange(t)[None, :]
    full_out, _, _ = D.draft_forward(
        heads["eagle"], dcfg, CFG, params["tok_emb"], params["lm_head"],
        feats, toks, pos, None, _causal_bias(t, t), None,
    )
    cache = D.init_draft_cache(CFG, 1)
    cols = jnp.arange(CFG.max_len)[None, None, :]
    for i in range(t):
        cl = jnp.array([i], jnp.int32)
        bias = jnp.where(cols <= cl[:, None, None], 0.0, M.NEG).astype(jnp.float32)
        out_i, _, cache = D.draft_forward(
            heads["eagle"], dcfg, CFG, params["tok_emb"], params["lm_head"],
            feats[:, i : i + 1], toks[:, i : i + 1],
            cl[:, None], cl[:, None], bias, cache,
        )
        np.testing.assert_allclose(
            np.asarray(out_i[0, 0]), np.asarray(full_out[0, i]), atol=1e-4,
            err_msg=f"step {i}",
        )


def test_medusa_shapes(setup):
    _, _ = setup
    mp = D.init_medusa_params(CFG, jax.random.PRNGKey(10))
    feat = jax.random.normal(jax.random.PRNGKey(11), (3, CFG.d))
    out = D.medusa_forward(mp, feat)
    assert out.shape == (3, D.MEDUSA_K, CFG.vocab)


def test_tdlm_config_is_small():
    tc = D.tdlm_config(CFG)
    assert tc.d < CFG.d or tc.n_layers <= 2
    assert not tc.is_moe
