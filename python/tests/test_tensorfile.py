"""stensor container + canonical pytree flattening (the L3 weights ABI)."""

import numpy as np
import pytest

from compile.tensorfile import flatten_params, read_stensor, unflatten_like, write_stensor


def test_roundtrip(tmp_path):
    tensors = [
        ("a.w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b", np.array([1, 2, 3], np.int32)),
        ("scalar", np.float32(7.0).reshape(())),
    ]
    p = str(tmp_path / "t.stensor")
    write_stensor(p, tensors)
    out = read_stensor(p)
    assert [n for n, _ in out] == ["a.w", "b", "scalar"]
    for (n1, a1), (n2, a2) in zip(tensors, out):
        assert a1.dtype == a2.dtype and a1.shape == a2.shape
        np.testing.assert_array_equal(a1, a2)


def test_flatten_deterministic_order():
    tree = {"z": np.zeros(2, np.float32), "a": [np.ones(1, np.float32), {"k": np.zeros(3, np.float32)}]}
    names = [n for n, _ in flatten_params(tree)]
    assert names == ["a.0", "a.1.k", "z"]  # dict keys sorted, lists positional


def test_unflatten_inverse():
    tree = {"layers": [{"w": np.random.rand(2, 2).astype(np.float32)} for _ in range(3)], "emb": np.random.rand(4).astype(np.float32)}
    flat = flatten_params(tree)
    rebuilt = unflatten_like(tree, flat)
    np.testing.assert_array_equal(np.asarray(rebuilt["layers"][1]["w"]), tree["layers"][1]["w"])
    np.testing.assert_array_equal(np.asarray(rebuilt["emb"]), tree["emb"])


def test_bad_dtype_rejected(tmp_path):
    with pytest.raises(ValueError):
        write_stensor(str(tmp_path / "x.stensor"), [("f64", np.zeros(2, np.float64))])
