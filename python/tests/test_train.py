"""Training stack: optimizer math, loss decrease smoke, feature extraction
consistency, greedy generation shape."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model as M, train
from compile.optim import adamw_update, clip_by_global_norm, cosine_lr, init_opt_state
from compile.tokenizer import train_bpe

TINY = replace(M.toy_s(), vocab=0, d=32, n_layers=1, n_heads=1, head_dim=32, ffn=48, max_len=48)


@pytest.fixture(scope="module")
def corpus():
    ds = data.gen_dialogues(200, 3)
    bpe = train_bpe(data.corpus_text(ds), 150)
    streams = [bpe.encode_dialogue(d["user"], d["asst"]) for d in ds]
    chunks = train.pack_chunks(streams, train.SEQ_LEN)
    return bpe, chunks


def test_smooth_l1():
    x = jnp.array([0.0, 0.5, 2.0])
    y = jnp.zeros(3)
    out = np.asarray(train.smooth_l1(x, y))
    np.testing.assert_allclose(out, [0.0, 0.125, 1.5])


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], atol=1e-5)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.full((1,), 0.1)}
    st = init_opt_state(p)
    p2, st2, _ = adamw_update(p, g, st, jnp.asarray(0.01), wd=0.0)
    # bias-corrected first step ~= lr * sign(g)
    assert abs(float(p2["w"][0]) + 0.01) < 2e-3
    assert int(st2["step"]) == 1


def test_cosine_lr_monotone_sections():
    base = 1e-3
    warm = float(cosine_lr(jnp.asarray(5), base, 10, 100))
    peak = float(cosine_lr(jnp.asarray(10), base, 10, 100))
    end = float(cosine_lr(jnp.asarray(100), base, 10, 100))
    assert warm < peak and end < peak and end < 1e-4


def test_target_loss_decreases(corpus):
    bpe, chunks = corpus
    cfg = replace(TINY, vocab=bpe.vocab_size)
    _, losses = train.train_target(cfg, chunks, steps=30, log=lambda *_: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_extract_features_matches_forward(corpus):
    bpe, chunks = corpus
    cfg = replace(TINY, vocab=bpe.vocab_size)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    feats = train.extract_features(params, cfg, chunks[:4])
    assert feats.shape == (4, train.SEQ_LEN, cfg.d)
    # spot-check one row against a direct forward
    t = chunks.shape[1]
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(t)[None, None, :]
    bias = jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)
    pos = jnp.arange(t)[None, :]
    _, f, _, _, _ = M.forward(
        params, replace(cfg, attn_impl="ref"), jnp.asarray(chunks[:1]), pos, None, bias, None
    )
    np.testing.assert_allclose(feats[0], np.asarray(f[0]), atol=1e-4)


def test_draft_head_trains_and_beats_chance(corpus):
    bpe, chunks = corpus
    cfg = replace(TINY, vocab=bpe.vocab_size)
    params, _ = train.train_target(cfg, chunks, steps=40, log=lambda *_: None)
    feats = train.extract_features(params, cfg, chunks, max_chunks=64)
    dp = train.train_draft_head("eagle", params, cfg, chunks[:64], feats, steps=40, log=lambda *_: None)
    acc = train.draft_top1_accuracy(dp, "eagle", params, cfg, chunks[:64], feats, n_eval=16)
    assert acc > 5.0 / cfg.vocab, f"draft accuracy {acc} at chance level"


def test_generate_greedy_shapes(corpus):
    bpe, chunks = corpus
    cfg = replace(TINY, vocab=bpe.vocab_size)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    out = train.generate_greedy(params, cfg, chunks[:8, :16], 8)
    assert out.shape == (8, 24)
    np.testing.assert_array_equal(out[:, :16], chunks[:8, :16])
