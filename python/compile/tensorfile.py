"""`.stensor` — minimal binary tensor container (S8).

Weights are *not* baked into the HLO (keeps artifacts small and lets one
compiled graph serve many checkpoints, e.g. the Table-6 ablation heads).
Python writes this container; rust (`rust/src/runtime/tensorfile.rs`)
reads it and uploads each entry once as a device-resident PJRT buffer.

Layout (little-endian, fully sequential):
    magic   8 bytes  b"STNSR1\\0\\0"
    count   u32
    entry × count:
        name_len u32, name utf-8,
        dtype    u8 (0 = f32, 1 = i32),
        ndim     u32, dims u64 × ndim,
        payload  raw bytes (row-major)
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"STNSR1\x00\x00"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write_stensor(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            shape = np.asarray(arr).shape
            arr = np.ascontiguousarray(arr).reshape(shape)  # keep 0-d 0-d
            if arr.dtype not in DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read_stensor(path: str) -> list[tuple[str, np.ndarray]]:
    out = []
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (dt,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            dtype = np.dtype(DTYPES_INV[dt])
            n = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype).reshape(dims)
            out.append((name, arr))
    return out


# -- canonical flattening of parameter pytrees ------------------------------
# The order here is the ABI between aot.py (writes weights + manifest input
# lists) and the rust runtime (feeds buffers positionally).


def flatten_params(params) -> list[tuple[str, np.ndarray]]:
    """Deterministic (path, leaf) list for a nested dict/list pytree."""
    out: list[tuple[str, np.ndarray]] = []

    def rec(prefix: str, node):
        if isinstance(node, dict):
            for k in sorted(node.keys()):
                rec(f"{prefix}.{k}" if prefix else k, node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}.{i}", v)
        else:
            out.append((prefix, np.asarray(node)))

    rec("", params)
    return out


def unflatten_like(template, flat: list[tuple[str, np.ndarray]]):
    """Rebuild a pytree shaped like `template` from flatten_params output."""
    lookup = dict(flat)

    def rec(prefix: str, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}.{k}" if prefix else k, v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [rec(f"{prefix}.{i}", v) for i, v in enumerate(node)]
        import jax.numpy as jnp

        return jnp.asarray(lookup[prefix])

    return rec("", template)
