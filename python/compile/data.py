"""Synthetic multi-task dialogue corpus + evaluation workloads (S2).

Stands in for ShareGPT (training) and MT-bench / GSM8K (evaluation) — see
DESIGN.md §Substitutions. Eight MT-bench-like categories with *deliberately
different regularity*: `coding` is highly templated (highest draft
acceptance, mirroring Fig. 8), `writing`/`roleplay` are the most entropic.

Everything is seeded and deterministic.
"""

from __future__ import annotations

import json
import random

CATEGORIES = [
    "writing",
    "roleplay",
    "reasoning",
    "math",
    "coding",
    "extraction",
    "stem",
    "humanities",
]

_NAMES = ["tom", "anna", "ravi", "mei", "lucas", "sara", "ivan", "noor"]
_ITEMS = ["apples", "books", "coins", "stones", "cards", "shells"]
_ADJ = ["quiet", "bright", "ancient", "gentle", "rapid", "hollow", "vivid"]
_NOUN = ["river", "garden", "engine", "castle", "signal", "forest", "harbor"]
_VERB = ["follows", "guards", "crosses", "repairs", "observes", "carries"]
_ELEMENTS = ["iron", "copper", "helium", "carbon", "silicon", "sodium"]
_PROPS = ["density", "melting point", "atomic mass", "boiling point"]
_PEOPLE = ["the poet", "the historian", "the painter", "the composer"]
_WORKS = ["a long letter", "a short treatise", "a quiet elegy", "a field diary"]
_OPS = [("plus", lambda a, b: a + b), ("minus", lambda a, b: a - b), ("times", lambda a, b: a * b)]


def _gen_writing(r: random.Random) -> tuple[str, str]:
    topic = f"the {r.choice(_ADJ)} {r.choice(_NOUN)}"
    q = f"write two sentences about {topic}."
    s = []
    for _ in range(2):
        s.append(
            f"the {r.choice(_ADJ)} {r.choice(_NOUN)} {r.choice(_VERB)} "
            f"the {r.choice(_ADJ)} {r.choice(_NOUN)}."
        )
    return q, " ".join(s)


def _gen_roleplay(r: random.Random) -> tuple[str, str]:
    who = r.choice(_NAMES)
    q = f"you are {who} the keeper of the {r.choice(_NOUN)}. greet a visitor."
    a = (
        f"welcome traveler. i am {who}, keeper of this {r.choice(_NOUN)}. "
        f"the {r.choice(_ADJ)} {r.choice(_NOUN)} {r.choice(_VERB)} the path ahead."
    )
    return q, a


def _gen_reasoning(r: random.Random) -> tuple[str, str]:
    x, y = r.sample(_NOUN, 2)
    z = r.choice(_NAMES)
    q = f"if all {x}s are {r.choice(_ADJ)} and {z} owns a {x}, what follows?"
    a = f"since all {x}s are {r.choice(_ADJ)}, the {x} that {z} owns is also like that. so {z} owns one such {x}."
    return q, a


def _gen_math(r: random.Random) -> tuple[str, str]:
    name = r.choice(_NAMES)
    item = r.choice(_ITEMS)
    a0 = r.randint(2, 30)
    b0 = r.randint(2, 20)
    c0 = r.randint(1, min(9, a0))
    s1 = a0 + b0
    s2 = s1 - c0
    q = (
        f"{name} has {a0} {item}. {name} buys {b0} more and gives away {c0}. "
        f"how many {item} remain?"
    )
    a = (
        f"start with {a0}. after buying {b0} there are {a0} plus {b0} which is {s1}. "
        f"after giving away {c0} there are {s1} minus {c0} which is {s2}. "
        f"the answer is {s2}."
    )
    return q, a


def _gen_coding(r: random.Random) -> tuple[str, str]:
    fn = f"f{r.randint(1, 40)}"
    op = r.choice(["+", "-", "*"])
    k = r.randint(1, 9)
    n = r.randint(2, 6)
    q = f"write a function {fn} that maps x to x {op} {k} and apply it to range {n}."
    body = f"def {fn}(x):\n    return x {op} {k}\n\nresult = []\nfor i in range({n}):\n    result.append({fn}(i))\nprint(result)"
    return q, body


def _gen_extraction(r: random.Random) -> tuple[str, str]:
    name = r.choice(_NAMES)
    age = r.randint(18, 80)
    city = r.choice(_NOUN)
    q = f"record: name {name}; age {age}; city {city}. extract the age of {name}."
    a = f"the age of {name} is {age}."
    return q, a


def _gen_stem(r: random.Random) -> tuple[str, str]:
    el = r.choice(_ELEMENTS)
    pr = r.choice(_PROPS)
    v = r.randint(10, 999)
    q = f"state the {pr} of {el}."
    a = f"the {pr} of {el} is {v} units. this value places {el} among the common elements."
    return q, a


def _gen_humanities(r: random.Random) -> tuple[str, str]:
    y = r.randint(1400, 1990)
    p = r.choice(_PEOPLE)
    w = r.choice(_WORKS)
    q = f"what did {p} write in {y}?"
    a = f"in {y} {p} wrote {w}. the work describes the {r.choice(_ADJ)} {r.choice(_NOUN)} of that era."
    return q, a


_GENS = {
    "writing": _gen_writing,
    "roleplay": _gen_roleplay,
    "reasoning": _gen_reasoning,
    "math": _gen_math,
    "coding": _gen_coding,
    "extraction": _gen_extraction,
    "stem": _gen_stem,
    "humanities": _gen_humanities,
}


def gen_dialogues(n: int, seed: int, categories: list[str] | None = None) -> list[dict]:
    """n (category, question, answer) dialogues, round-robin over categories."""
    cats = categories or CATEGORIES
    r = random.Random(seed)
    out = []
    for i in range(n):
        c = cats[i % len(cats)]
        q, a = _GENS[c](r)
        out.append({"category": c, "user": q, "asst": a})
    return out


def corpus_text(dialogues: list[dict]) -> str:
    """Raw text for BPE training."""
    return "\n".join(d["user"] + "\n" + d["asst"] for d in dialogues)


def eval_workload(name: str, n: int, seed: int) -> dict:
    """Held-out evaluation prompts. `mtbench` = all 8 categories;
    `gsm8k` = math-only multi-step arithmetic."""
    cats = CATEGORIES if name == "mtbench" else ["math"]
    ds = gen_dialogues(n, seed, cats)
    return {
        "name": name,
        "prompts": [{"category": d["category"], "user": d["user"]} for d in ds],
    }


def write_workloads(out_dir: str, seed: int = 7331) -> None:
    import os

    os.makedirs(out_dir, exist_ok=True)
    for name, n, off in [("mtbench", 64, 101), ("gsm8k", 32, 202)]:
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(eval_workload(name, n, seed + off), f, indent=1)
