"""int8 weight-only quantization (S20) — the gpt-fast composition analog.

Per-output-channel symmetric int8 for every 2-D matmul weight; embeddings,
norms and biases stay fp32. The executables dequantize in-graph, so the
weight *container* shrinks ~4x while the compute graph stays identical —
on this CPU-f32 substrate that demonstrates the composition claim
(Table 4: EAGLE stacks with quantization) through memory, not wallclock;
see EXPERIMENTS.md tab4 notes.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .tensorfile import flatten_params, read_stensor, write_stensor


KEEP_FP32 = ("tok_emb", "lm_head")  # shared with the fp32 draft head (and
# gpt-fast likewise keeps embeddings unquantized)


def quantize_leaf(name: str, arr: np.ndarray):
    """-> list of (name, array) replacing the leaf."""
    if name in KEEP_FP32:
        return [(name, arr)]
    if arr.ndim == 2 and arr.dtype == np.float32 and min(arr.shape) >= 64:
        scale = np.abs(arr).max(axis=0, keepdims=True) / 127.0 + 1e-12
        q = np.clip(np.round(arr / scale), -127, 127).astype(np.int32)
        return [(f"{name}.q", q), (f"{name}.scale", scale.astype(np.float32))]
    return [(name, arr)]


def quantize_params(flat: list[tuple[str, np.ndarray]]):
    out = []
    for name, arr in flat:
        out.extend(quantize_leaf(name, np.asarray(arr)))
    return out


def dequant_tree(qflat: list[tuple[str, jnp.ndarray]]):
    """Inverse of quantize_params at the flat-name level (in-graph)."""
    out = []
    i = 0
    while i < len(qflat):
        name, arr = qflat[i]
        if name.endswith(".q"):
            scale = qflat[i + 1][1]
            out.append((name[:-2], arr.astype(jnp.float32) * scale))
            i += 2
        else:
            out.append((name, arr))
            i += 1
    return out


def build_quant(out: str, manifest: dict, cfg: M.ModelConfig) -> None:
    """Lower int8 variants of the toy-s serving executables + eagle head."""
    from . import aot  # late import to avoid cycle
    from .tensorfile import unflatten_like

    src = manifest["models"]["toy-s"]
    params_flat = read_stensor(os.path.join(out, src["weights"]))
    qflat = quantize_params(params_flat)
    write_stensor(os.path.join(out, "weights/toy-s-int8.stensor"), qflat)

    # template for unflatten
    import jax.numpy as jnp

    template = unflatten_like(
        M.init_params(cfg, jax.random.PRNGKey(0)), params_flat
    )

    qnames = [n for n, _ in qflat]
    qspecs = [jax.ShapeDtypeStruct(a.shape, jnp.int32 if a.dtype == np.int32 else jnp.float32) for _, a in qflat]

    class QuantTargetLowering(aot.TargetLowering):
        def __init__(self):
            self.cfg = cfg
            self.params = template
            self.names = qnames
            self.specs = qspecs

        def _unflatten(self, leaves):
            deq = dequant_tree(list(zip(qnames, leaves)))
            return unflatten_like(self.params, deq)

    tl = QuantTargetLowering()
    exes = {}
    jobs = {
        "prefill": tl.prefill(aot.PREFILL_P, 1),
        "decode": tl.decode(1),
    }
    # the same verify-width family as the fp32 targets, so width
    # selection composes with quantization (Table 4 analog)
    for t in sorted(aot.VERIFY_WIDTHS):
        jobs[f"verify_t{t}"] = tl.verify(t, aot.ACCEPT_A, 1)
    for ename, (fn, ex) in jobs.items():
        path = f"hlo/toy-s-int8.{ename}.hlo.txt"
        aot.lower_to_file(fn, ex, os.path.join(out, path))
        exes[ename] = {"hlo": path, "bs": 1}
        print(f"[aot] lowered toy-s-int8.{ename}")

    manifest["models"]["toy-s-int8"] = {
        "config": src["config"],
        "weights": "weights/toy-s-int8.stensor",
        "param_names": qnames,
        "executables": exes,
        # reuse the fp32 eagle head against the int8 target; the full
        # step_w{w} draft-width family (and its _bs{b} variants) rides
        # along, so per-level draft-width fits compose with quantization
        # exactly like verify-width selection does
        "drafts": {"eagle": src["drafts"]["eagle"]},
        "quantized": True,
    }
