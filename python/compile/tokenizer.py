"""Byte-level BPE tokenizer (S1).

Trained in python at artifact-build time; the exact same greedy-merge
encoder is re-implemented in rust (`rust/src/text/bpe.rs`). The vocab
artifact (`artifacts/vocab.json`) carries the merge table in rank order,
so both sides are bit-identical; `python/tests/test_tokenizer.py` dumps
fixtures that the rust test suite replays.

Id layout:
    0..255          raw bytes
    256..256+M-1    merges, in rank order
    256+M..         specials: <pad>, <bos>, <eos>, <user>, <asst>
"""

from __future__ import annotations

import json
from collections import Counter

SPECIALS = ["<pad>", "<bos>", "<eos>", "<user>", "<asst>"]


def split_words(text: str) -> list[str]:
    """Split into pieces of (optional single leading space + non-space run).

    Lone/extra spaces become single-space pieces. Mirrored exactly in rust.
    """
    words: list[str] = []
    i, n = 0, len(text)
    while i < n:
        j = i
        if text[i] == " ":
            j = i + 1
        k = j
        while k < n and text[k] != " ":
            k += 1
        if k == j:  # the piece is a lone space
            words.append(" ")
            i = j
        else:
            words.append(text[i:k])
            i = k
    return words


class Bpe:
    def __init__(self, merges: list[tuple[int, int]]):
        self.merges = merges
        # (left, right) -> merged id; merged id = 256 + rank
        self.ranks = {pair: 256 + r for r, pair in enumerate(merges)}
        self.vocab_size = 256 + len(merges) + len(SPECIALS)
        self.special_ids = {s: 256 + len(merges) + i for i, s in enumerate(SPECIALS)}
        self._cache: dict[str, list[int]] = {}

    # -- encoding ---------------------------------------------------------
    def encode_word(self, word: str) -> list[int]:
        if word in self._cache:
            return self._cache[word]
        ids = list(word.encode("utf-8"))
        while len(ids) >= 2:
            best_rank, best_i = None, -1
            for i in range(len(ids) - 1):
                r = self.ranks.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            ids = ids[:best_i] + [best_rank] + ids[best_i + 2 :]
        self._cache[word] = ids
        return ids

    def encode(self, text: str) -> list[int]:
        out: list[int] = []
        for w in split_words(text):
            out.extend(self.encode_word(w))
        return out

    def encode_dialogue(self, user: str, asst: str | None = None) -> list[int]:
        """<bos> <user> ...prompt... <asst> [...answer... <eos>]"""
        ids = [self.special_ids["<bos>"], self.special_ids["<user>"]]
        ids += self.encode(user)
        ids.append(self.special_ids["<asst>"])
        if asst is not None:
            ids += self.encode(asst)
            ids.append(self.special_ids["<eos>"])
        return ids

    # -- decoding ---------------------------------------------------------
    def expand(self, tid: int) -> bytes:
        if tid < 256:
            return bytes([tid])
        if tid - 256 < len(self.merges):
            l, r = self.merges[tid - 256]
            return self.expand(l) + self.expand(r)
        return SPECIALS[tid - 256 - len(self.merges)].encode()

    def decode(self, ids: list[int]) -> str:
        return b"".join(self.expand(t) for t in ids).decode("utf-8", errors="replace")

    # -- persistence ------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "merges": [[l, r] for l, r in self.merges],
                "specials": SPECIALS,
                "vocab_size": self.vocab_size,
            }
        )

    @staticmethod
    def from_json(s: str) -> "Bpe":
        d = json.loads(s)
        return Bpe([(l, r) for l, r in d["merges"]])


def train_bpe(corpus: str, n_merges: int) -> Bpe:
    """Classic BPE training over word-frequency table with incremental pair
    counts. Deterministic: ties broken by smallest pair ids."""
    word_freq = Counter(split_words(corpus))
    # each distinct word: (list of symbol ids, freq)
    words = [(list(w.encode("utf-8")), f) for w, f in word_freq.items()]
    merges: list[tuple[int, int]] = []

    def pair_counts() -> Counter:
        c: Counter = Counter()
        for syms, f in words:
            for a, b in zip(syms, syms[1:]):
                c[(a, b)] += f
        return c

    counts = pair_counts()
    for rank in range(n_merges):
        if not counts:
            break
        # deterministic argmax: max count, then lexicographically smallest
        best = min(counts.items(), key=lambda kv: (-kv[1], kv[0]))[0]
        if counts[best] < 2:
            break
        new_id = 256 + rank
        merges.append(best)
        for syms, f in words:
            i = 0
            while i < len(syms) - 1:
                if syms[i] == best[0] and syms[i + 1] == best[1]:
                    # update counts around the merge site
                    if i > 0:
                        counts[(syms[i - 1], syms[i])] -= f
                        counts[(syms[i - 1], new_id)] += f
                    if i + 2 < len(syms):
                        counts[(syms[i + 1], syms[i + 2])] -= f
                        counts[(new_id, syms[i + 2])] += f
                    syms[i : i + 2] = [new_id]
                else:
                    i += 1
        del counts[best]
        counts = Counter({k: v for k, v in counts.items() if v > 0})
    return Bpe(merges)
