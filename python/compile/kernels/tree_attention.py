"""L1 — Pallas tree-attention kernel (S4).

Flash-style attention over (committed KV cache + draft-tree region) with an
arbitrary additive mask: the compute hot-spot of both EAGLE drafting and
tree verification.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over (batch, head); the
query block (draft tree, T ≤ 128 rows) is pinned in VMEM; K/V stream
through VMEM in `BLOCK_S`-row tiles via a `fori_loop`, with the online-
softmax running statistics (m, l, acc) held in VMEM scratch across tiles —
the role shared memory / registers play in the CUDA FlashAttention the
paper's GPU implementations splice their tree mask into. Both GEMMs
(Q·Kᵀ and P·V) are `jnp.dot`s shaped for the 128×128 MXU; masking is an
additive-bias `select` on the VPU (no divergent control flow).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
loads (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 96  # KV-tile rows per VMEM stage (S_tot must be a multiple)


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, block_s: int):
    # q_ref: [T, dh]; k_ref/v_ref: [S, dh]; bias_ref: [T, S]; o_ref: [T, dh]
    t, dh = q_ref.shape
    s_tot = k_ref.shape[0]
    n_tiles = s_tot // block_s
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    q = q_ref[...].astype(jnp.float32) * scale

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        ks = k_ref[pl.ds(i * block_s, block_s), :].astype(jnp.float32)
        vs = v_ref[pl.ds(i * block_s, block_s), :].astype(jnp.float32)
        bs = bias_ref[:, pl.ds(i * block_s, block_s)].astype(jnp.float32)
        s = jnp.dot(q, ks.T) + bs  # [T, block_s] — MXU
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked tiles: exp(-inf - -inf) -> use finite floor
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)  # VPU select = tree mask
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(p, vs)  # MXU
        return m_new, l_new, acc_new

    m0 = jnp.full((t,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((t,), jnp.float32)
    acc0 = jnp.zeros((t, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    o_ref[...] = (acc / (l[:, None] + 1e-30)).astype(o_ref.dtype)


def tree_attention(
    q: jnp.ndarray,  # [B, T, H, dh]
    k: jnp.ndarray,  # [B, S, H, dh]
    v: jnp.ndarray,  # [B, S, H, dh]
    bias: jnp.ndarray,  # [B, T, S]
    *,
    block_s: int = BLOCK_S,
) -> jnp.ndarray:
    b, t, h, dh = q.shape
    s_tot = k.shape[1]
    if s_tot % block_s != 0:
        # fall back to one tile spanning S (still flash-structured)
        block_s = s_tot
    kern = functools.partial(_kernel, block_s=block_s)
    out = pl.pallas_call(
        kern,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((None, t, None, dh), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, s_tot, None, dh), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, s_tot, None, dh), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, t, s_tot), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, None, dh), lambda i, j: (i, 0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, dh), q.dtype),
        interpret=True,
    )(q, k, v, bias)
    return out
