"""Pure-jnp oracle for the tree-attention kernel (S4).

This is the ground truth the Pallas kernel is validated against
(`python/tests/test_kernel.py`, hypothesis sweeps) and the fallback
attention implementation selectable via `ModelConfig.attn_impl`.
"""

from __future__ import annotations

import jax.numpy as jnp


def tree_attention_ref(
    q: jnp.ndarray,  # [B, T, H, dh]
    k: jnp.ndarray,  # [B, S, H, dh]
    v: jnp.ndarray,  # [B, S, H, dh]
    bias: jnp.ndarray,  # [B, T, S] additive (0 or -inf-ish)
) -> jnp.ndarray:  # [B, T, H, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    # [B, H, T, S]
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    scores = scores + bias[:, None, :, :].astype(scores.dtype)
    w = jnp.nan_to_num(jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True)))
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-30)
    return jnp.einsum("bhts,bshd->bthd", w, v).astype(q.dtype)
