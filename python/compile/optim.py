"""AdamW + global-norm clip + warmup-cosine schedule (S7).

optax is not available in this environment; this is the standard algorithm
written directly over pytrees. Paper settings reused for the draft heads:
betas (0.9, 0.95), gradient clip 0.5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_update(
    params,
    grads,
    state,
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    wd: float = 0.01,
    clip: float = 0.5,
):
    grads, gnorm = clip_by_global_norm(grads, clip)
    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, gnorm


def cosine_lr(step: jnp.ndarray, base: float, warmup: int, total: int) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = base * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
