"""L2 — draft models (S5, S6).

The EAGLE Auto-regression Head and its ablation variants (paper §5.3.2),
the Medusa baseline heads, and a token-level draft LM for the classic
two-model speculative-sampling baseline.

EAGLE head = FC(concat(emb(token), feature)) + one decoder layer, with the
target's Embedding and LM Head reused frozen (paper Fig. 7). The four
input variants (Fig. 10):

    eagle    input_i = concat(emb(t_{i+1}), f_i)   — shifted token: the
             sampling outcome is in the input, resolving uncertainty
    unshift  input_i = concat(emb(t_i),     f_i)
    feat     input_i = f_i
    tok      input_i = emb(t_i)

All predict f̂_{i+1} (the next feature); tokens come from the frozen LM
head on f̂. The draft model runs its own KV cache with the same unified
cache-forward contract as the target (prefill / tree-step / commit).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .model import ModelConfig, rmsnorm, rope, swiglu, NEG
from .kernels.ref import tree_attention_ref
from .kernels.tree_attention import tree_attention

VARIANTS = ("eagle", "unshift", "feat", "tok")


@dataclass(frozen=True)
class DraftConfig:
    variant: str = "eagle"
    ffn: int = 688

    def uses_feature(self) -> bool:
        return self.variant in ("eagle", "unshift", "feat")

    def uses_token(self) -> bool:
        return self.variant in ("eagle", "unshift", "tok")

    def fused(self) -> bool:
        return self.variant in ("eagle", "unshift")


def init_draft_params(dcfg: DraftConfig, cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 9)

    def dense(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) * (2.0 / (i + o)) ** 0.5

    d = cfg.d
    hd = cfg.n_heads * cfg.head_dim
    in_dim = 2 * d if dcfg.fused() else d
    return {
        "fc": dense(ks[0], in_dim, d),
        "ln1": jnp.ones((d,), jnp.float32),
        "wq": dense(ks[1], d, hd),
        "wk": dense(ks[2], d, hd),
        "wv": dense(ks[3], d, hd),
        "wo": dense(ks[4], hd, d),
        "ln2": jnp.ones((d,), jnp.float32),
        "w1": dense(ks[5], d, dcfg.ffn),
        "w2": dense(ks[6], dcfg.ffn, d),
        "w3": dense(ks[7], d, dcfg.ffn),
    }


def init_draft_cache(cfg: ModelConfig, batch: int = 1) -> jnp.ndarray:
    return jnp.zeros((2, batch, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32)


def draft_inputs(
    dcfg: DraftConfig, tok_emb: jnp.ndarray, feats: jnp.ndarray, tokens: jnp.ndarray
) -> jnp.ndarray:
    """Assemble the variant-specific input sequence. `tokens` must already
    be shifted by the caller for the `eagle` variant."""
    e = tok_emb[tokens]
    if dcfg.variant in ("eagle", "unshift"):
        return jnp.concatenate([e, feats], axis=-1)
    if dcfg.variant == "feat":
        return feats
    return e  # tok


def draft_forward(
    dparams: dict,
    dcfg: DraftConfig,
    cfg: ModelConfig,
    tok_emb: jnp.ndarray,  # frozen target embedding [V, D]
    lm_head: jnp.ndarray,  # frozen target LM head [D, V]
    feats: jnp.ndarray,  # [B, T, D] (ignored by `tok`)
    tokens: jnp.ndarray,  # [B, T] (ignored by `feat`)
    pos: jnp.ndarray,  # [B, T]
    write_pos: jnp.ndarray,  # [B, T]
    bias: jnp.ndarray,  # [B, T, S]
    cache: jnp.ndarray,  # [2, B, S, H, dh]
):
    """One decoder-layer pass. Returns (f̂ [B,T,D], logits [B,T,V], cache')."""
    b, t = tokens.shape
    x = draft_inputs(dcfg, tok_emb, feats, tokens) @ dparams["fc"]
    h = rmsnorm(x, dparams["ln1"])
    q = (h @ dparams["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = (h @ dparams["wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    v = (h @ dparams["wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    attn = tree_attention if cfg.attn_impl == "pallas" else tree_attention_ref
    if cache is None:  # training path
        o = attn(q, k, v, bias)
    else:
        batch_idx = jnp.arange(b)[:, None]
        cache = cache.at[0, batch_idx, write_pos].set(k)
        cache = cache.at[1, batch_idx, write_pos].set(v)
        o = attn(q, cache[0], cache[1], bias)
    x = x + o.reshape(b, t, -1) @ dparams["wo"]
    x = x + swiglu(dparams, rmsnorm(x, dparams["ln2"]))
    f_hat = rmsnorm(x, jnp.ones((cfg.d,), jnp.float32))  # predict normalized feature
    logits = f_hat @ lm_head
    return f_hat, logits, cache


# --------------------------------------------------------------------------
# Medusa baseline (S6): K residual-MLP heads predicting offsets 2..K+1
# --------------------------------------------------------------------------

MEDUSA_K = 4


def init_medusa_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, MEDUSA_K)
    heads = []
    for k in ks:
        k1, k2 = jax.random.split(k)
        heads.append(
            {
                "w": jax.random.normal(k1, (cfg.d, cfg.d), jnp.float32) * 0.02,
                "b": jnp.zeros((cfg.d,), jnp.float32),
                "head": jax.random.normal(k2, (cfg.d, cfg.vocab), jnp.float32) * 0.02,
            }
        )
    return {"heads": heads}


def medusa_forward(mparams: dict, feat: jnp.ndarray) -> jnp.ndarray:
    """feat [B, D] -> logits [B, K, V] for token offsets +2..+K+1
    (offset +1 comes from the target's own LM head)."""
    outs = []
    for h in mparams["heads"]:
        x = feat + jax.nn.silu(feat @ h["w"] + h["b"])  # ResBlock
        outs.append(x @ h["head"])
    return jnp.stack(outs, axis=1)


# --------------------------------------------------------------------------
# Token-level draft LM (classic speculative baseline): tiny 2-layer LM
# --------------------------------------------------------------------------


def tdlm_config(cfg: ModelConfig) -> ModelConfig:
    from dataclasses import replace

    return replace(
        cfg,
        name=f"tdlm-{cfg.name}",
        d=128,
        n_layers=2,
        n_heads=2,
        head_dim=64,
        ffn=344,
        n_experts=0,
    )
