"""Build-time training stack (S7).

Trains, on the synthetic corpus (S2):
  * the target LMs (toy-s / toy-m / toy-moe) — plain next-token CE;
  * the EAGLE Auto-regression Head + the three ablation heads — the paper's
    combined loss  L = SmoothL1(f̂, f) + 0.1·CE(p, p̂)  with U(-0.1, 0.1)
    feature-noise augmentation (paper §4.2);
  * Medusa heads (offset-k token CE) and the token-level draft LM.

Features for the draft heads are teacher-forced from the frozen target
*once* and reused across all head variants (the heads are the only thing
that differs). For the Table-6 ablation, training answers are regenerated
by the target LLM itself via a scan-based greedy decode.

Everything is deterministic (fixed PRNG keys) and sized for a single CPU
core — see DESIGN.md §Substitutions.
"""

from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import draft as D
from .optim import adamw_update, cosine_lr, init_opt_state

SEQ_LEN = 96
BATCH = 8
W_CLS = 0.1  # paper §4.2


# --------------------------------------------------------------------------
# data packing
# --------------------------------------------------------------------------


def pack_chunks(token_streams: list[list[int]], seq_len: int) -> np.ndarray:
    """Concatenate dialogue token streams and chunk to [N, seq_len]."""
    flat: list[int] = []
    for s in token_streams:
        flat.extend(s)
    n = len(flat) // seq_len
    return np.asarray(flat[: n * seq_len], np.int32).reshape(n, seq_len)


def batches(chunks: np.ndarray, batch: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = chunks.shape[0]
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        yield jnp.asarray(chunks[idx])


# --------------------------------------------------------------------------
# target LM training
# --------------------------------------------------------------------------


def _target_loss(params, cfg: M.ModelConfig, toks: jnp.ndarray, bias: jnp.ndarray, pos):
    logits, _, _, _, _ = M.forward(params, cfg, toks[:, :-1], pos, None, bias, None)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = toks[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_target(cfg: M.ModelConfig, chunks: np.ndarray, steps: int, lr: float = 3e-3, seed: int = 0, log=print):
    tcfg = replace(cfg, attn_impl="ref")  # ref attention for training speed
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    t = SEQ_LEN - 1
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(t)[None, None, :]
    bias = jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (BATCH, t))

    @jax.jit
    def step_fn(params, opt, toks, lr_now):
        loss, grads = jax.value_and_grad(_target_loss)(params, tcfg, toks, bias, pos)
        params, opt, gn = adamw_update(params, grads, opt, lr_now)
        return params, opt, loss

    losses = []
    for i, toks in enumerate(batches(chunks, BATCH, steps, seed + 1)):
        lr_now = cosine_lr(jnp.asarray(i), lr, warmup=20, total=steps)
        params, opt, loss = step_fn(params, opt, toks, lr_now)
        losses.append(float(loss))
        if i % 25 == 0 or i == steps - 1:
            log(f"[train {cfg.name}] step {i} loss {float(loss):.4f}")
    return params, losses


# --------------------------------------------------------------------------
# feature extraction (teacher forcing, frozen target)
# --------------------------------------------------------------------------


def extract_features(params, cfg: M.ModelConfig, chunks: np.ndarray, max_chunks: int = 800):
    """[N, T] tokens -> [N, T, D] post-ln_f features, batched."""
    tcfg = replace(cfg, attn_impl="ref")
    t = chunks.shape[1]
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(t)[None, None, :]
    bias = jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)

    @jax.jit
    def fwd(toks):
        pos = jnp.broadcast_to(jnp.arange(t)[None, :], toks.shape)
        _, feats, _, _, _ = M.forward(params, tcfg, toks, pos, None, bias, None)
        return feats

    chunks = chunks[:max_chunks]
    outs = []
    bs = 16
    for i in range(0, chunks.shape[0], bs):
        blk = chunks[i : i + bs]
        pad = bs - blk.shape[0]
        if pad:
            blk = np.concatenate([blk, np.zeros((pad, t), np.int32)])
        outs.append(np.asarray(fwd(jnp.asarray(blk)))[: bs - pad if pad else bs])
    return np.concatenate(outs)


# --------------------------------------------------------------------------
# target-generated data (Table 6 ablation): greedy continue after a prefix
# --------------------------------------------------------------------------


def generate_greedy(params, cfg: M.ModelConfig, prefixes: np.ndarray, gen_len: int):
    """prefixes [N, P] -> [N, P+gen_len] greedy continuations (scan-based)."""
    tcfg = replace(cfg, attn_impl="ref")
    b, p = BATCH, prefixes.shape[1]
    s = cfg.max_len

    @jax.jit
    def run(toks):
        cache = M.init_cache(cfg, b)
        pos = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p))
        bias = M.prefill_bias(cfg, p, jnp.full((b,), p, jnp.int32), b)
        logits, _, cache, _, _ = M.forward(params, tcfg, toks, pos, pos, bias, cache)
        last = jnp.argmax(logits[:, -1], axis=-1)

        def dec(carry, i):
            cache, tok = carry
            cur = p + i
            wp = jnp.full((b, 1), cur, jnp.int32)
            cols = jnp.arange(s)[None, None, :]
            bias1 = jnp.where(cols <= cur, 0.0, M.NEG).astype(jnp.float32)
            bias1 = jnp.broadcast_to(bias1, (b, 1, s))
            lg, _, cache, _, _ = M.forward(
                params, tcfg, tok[:, None], wp, wp, bias1, cache
            )
            nxt = jnp.argmax(lg[:, 0], axis=-1)
            return (cache, nxt), tok

        (_, _), toks_out = jax.lax.scan(dec, (cache, last), jnp.arange(gen_len))
        return jnp.concatenate([toks, toks_out.T], axis=1)

    outs = []
    n = prefixes.shape[0] - prefixes.shape[0] % b
    for i in range(0, n, b):
        outs.append(np.asarray(run(jnp.asarray(prefixes[i : i + b]))))
    return np.concatenate(outs)


# --------------------------------------------------------------------------
# draft-head training
# --------------------------------------------------------------------------


def smooth_l1(x, y, beta: float = 1.0):
    d = jnp.abs(x - y)
    return jnp.where(d < beta, 0.5 * d * d / beta, d - 0.5 * beta)


def _draft_loss(dparams, dcfg, cfg, tok_emb, lm_head, feats_in, toks_in, f_tgt, bias, pos, key):
    noise = jax.random.uniform(key, feats_in.shape, jnp.float32, -0.1, 0.1)
    f_hat, _, _ = D.draft_forward(
        dparams, dcfg, cfg, tok_emb, lm_head, feats_in + noise, toks_in, pos, None, bias, None
    )
    l_reg = jnp.mean(smooth_l1(f_hat, f_tgt))
    p_tgt = jax.nn.softmax(f_tgt @ lm_head, axis=-1)
    logp_hat = jax.nn.log_softmax(f_hat @ lm_head, axis=-1)
    l_cls = -jnp.mean(jnp.sum(p_tgt * logp_hat, axis=-1))
    return l_reg + W_CLS * l_cls, (l_reg, l_cls)


def train_draft_head(
    variant: str,
    target_params,
    cfg: M.ModelConfig,
    chunks: np.ndarray,
    feats: np.ndarray,
    steps: int,
    lr: float = 1e-3,
    seed: int = 10,
    log=print,
):
    """Train one head variant from precomputed teacher features."""
    tcfg = replace(cfg, attn_impl="ref")
    dcfg = D.DraftConfig(variant=variant, ffn=cfg.ffn)
    dparams = D.init_draft_params(dcfg, cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(dparams)
    tok_emb = target_params["tok_emb"]
    lm_head = target_params["lm_head"]
    t = chunks.shape[1] - 1
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(t)[None, None, :]
    bias = jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (BATCH, t))

    @jax.jit
    def step_fn(dparams, opt, feats_in, toks_in, f_tgt, lr_now, key):
        (loss, (lr_, lc_)), grads = jax.value_and_grad(_draft_loss, has_aux=True)(
            dparams, dcfg, tcfg, tok_emb, lm_head, feats_in, toks_in, f_tgt, bias, pos, key
        )
        dparams, opt, _ = adamw_update(dparams, grads, opt, lr_now)
        return dparams, opt, loss, lr_, lc_

    rng = np.random.default_rng(seed + 1)
    n = min(chunks.shape[0], feats.shape[0])
    key = jax.random.PRNGKey(seed + 2)
    for i in range(steps):
        idx = rng.integers(0, n, size=BATCH)
        toks = chunks[idx]
        fts = feats[idx]
        # variant input slicing (see draft.py docstring)
        toks_in = jnp.asarray(toks[:, 1:] if variant == "eagle" else toks[:, :-1])
        feats_in = jnp.asarray(fts[:, :-1])
        f_tgt = jnp.asarray(fts[:, 1:])
        key, sub = jax.random.split(key)
        lr_now = cosine_lr(jnp.asarray(i), lr, warmup=10, total=steps)
        dparams, opt, loss, l_reg, l_cls = step_fn(dparams, opt, feats_in, toks_in, f_tgt, lr_now, sub)
        if i % 25 == 0 or i == steps - 1:
            log(
                f"[draft {variant}/{cfg.name}] step {i} loss {float(loss):.4f} "
                f"reg {float(l_reg):.4f} cls {float(l_cls):.4f}"
            )
    return dparams


# --------------------------------------------------------------------------
# Medusa heads
# --------------------------------------------------------------------------


def train_medusa(target_params, cfg: M.ModelConfig, chunks: np.ndarray, feats: np.ndarray, steps: int, lr: float = 1e-3, seed: int = 20, log=print):
    mparams = D.init_medusa_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(mparams)
    k_heads = D.MEDUSA_K

    def loss_fn(mparams, fts, toks):
        # head k (0-based) predicts token at offset i+2+k from feature f_i
        t = fts.shape[1]
        usable = t - (k_heads + 1)
        logits = D.medusa_forward(mparams, fts[:, :usable].reshape(-1, fts.shape[-1]))
        logits = logits.reshape(fts.shape[0], usable, k_heads, -1)
        total = 0.0
        for k in range(k_heads):
            tgt = toks[:, 2 + k : usable + 2 + k]
            logp = jax.nn.log_softmax(logits[:, :, k], axis=-1)
            total += -jnp.mean(jnp.take_along_axis(logp, tgt[:, :, None], axis=-1))
        return total / k_heads

    @jax.jit
    def step_fn(mparams, opt, fts, toks, lr_now):
        loss, grads = jax.value_and_grad(loss_fn)(mparams, fts, toks)
        mparams, opt, _ = adamw_update(mparams, grads, opt, lr_now)
        return mparams, opt, loss

    rng = np.random.default_rng(seed + 1)
    n = min(chunks.shape[0], feats.shape[0])
    for i in range(steps):
        idx = rng.integers(0, n, size=BATCH)
        lr_now = cosine_lr(jnp.asarray(i), lr, warmup=10, total=steps)
        mparams, opt, loss = step_fn(mparams, opt, jnp.asarray(feats[idx]), jnp.asarray(chunks[idx]), lr_now)
        if i % 25 == 0 or i == steps - 1:
            log(f"[medusa/{cfg.name}] step {i} loss {float(loss):.4f}")
    return mparams


# --------------------------------------------------------------------------
# token-level draft LM (classic speculative baseline)
# --------------------------------------------------------------------------


def train_tdlm(cfg: M.ModelConfig, chunks: np.ndarray, steps: int, lr: float = 3e-3, seed: int = 30, log=print):
    tcfg = D.tdlm_config(cfg)
    params, losses = train_target(tcfg, chunks, steps, lr=lr, seed=seed, log=log)
    return tcfg, params


# --------------------------------------------------------------------------
# quick quality probes (recorded into the manifest / EXPERIMENTS.md)
# --------------------------------------------------------------------------


def draft_top1_accuracy(dparams, variant, target_params, cfg, chunks, feats, n_eval: int = 32) -> float:
    """Fraction of positions where the head's argmax token equals the
    target's argmax token (the paper's ~0.8 'draft accuracy' probe)."""
    tcfg = replace(cfg, attn_impl="ref")
    dcfg = D.DraftConfig(variant=variant, ffn=cfg.ffn)
    toks = chunks[:n_eval]
    fts = feats[:n_eval]
    t = toks.shape[1] - 1
    rows = jnp.arange(t)[None, :, None]
    cols = jnp.arange(t)[None, None, :]
    bias = jnp.where(cols <= rows, 0.0, M.NEG).astype(jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (toks.shape[0], t))
    toks_in = jnp.asarray(toks[:, 1:] if variant == "eagle" else toks[:, :-1])
    f_hat, logits, _ = D.draft_forward(
        dparams, dcfg, tcfg, target_params["tok_emb"], target_params["lm_head"],
        jnp.asarray(fts[:, :-1]), toks_in, pos, None, bias, None,
    )
    tgt_logits = jnp.asarray(fts[:, 1:]) @ target_params["lm_head"]
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.argmax(tgt_logits, -1)))
