"""AOT pipeline (S8): train (cached) → lower → artifacts/.

Produces, under `artifacts/`:
    vocab.json                    tokenizer merge table
    workloads/{mtbench,gsm8k}.json  held-out eval prompts
    weights/<model|head>.stensor  parameter containers (device-uploaded once)
    hlo/<name>.hlo.txt            HLO text per executable (see DESIGN.md §3)
    manifest.json                 configs + executable catalog (the L3 ABI)
    train_log.json                losses / draft accuracies for EXPERIMENTS.md
    ckpt/                         training checkpoints (cache; delete to retrain)

HLO **text** is the interchange format — jax ≥ 0.5 serialized protos use
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Calling convention (positional, mirrored by rust/src/models/):
    target exe:  [param leaves (flatten_params order)] + call inputs
    draft  exe:  [draft leaves] + [tok_emb, lm_head] + call inputs
Verify/draft-step attention *bias* is an input — the rust coordinator owns
tree topology (S11) and builds the additive mask host-side.

Python runs ONCE; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import asdict, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from . import draft as D
from . import model as M
from . import quant as Q
from . import tokenizer as T
from . import train
from .tensorfile import flatten_params, read_stensor, unflatten_like, write_stensor

SEED = 1234
N_DIALOGUES = 1600
N_MERGES = 500
PREFILL_P = 64
TREE_T = 32  # max tree-verify width
CHAIN_T = 8  # chain-verify width (classic spec / alpha measurements)
# Verify-width family ("verify_widths" manifest constant): one
# verify_t{t} executable per width (plus _bs{b} variants for batched
# serving), so the rust engines can dispatch each round to the cheapest
# width that holds its draft tree (spec/dyntree/widths.rs). Must contain
# TREE_T; containing CHAIN_T keeps the chain engines on a shared lowering.
VERIFY_WIDTHS = (8, 16, TREE_T)
ACCEPT_A = 8  # max tokens committed per verification
DRAFT_W = 8  # tree draft level width
# Draft-step width family ("draft_widths" manifest constant): one
# step_w{w} executable per width, plus step_w{w}_bs{b} variants wherever
# batched serving is lowered. The engines run each draft level at the
# narrowest width holding its frontier, and the width-grouped scheduler
# relies on the batched variants so a low-acceptance lane GROUP drafts
# chain-like (w1/w4) instead of riding a hot lane's full-width step.
DRAFT_WIDTHS = (1, 4, DRAFT_W)
FAST = os.environ.get("EAGLE_FAST", "") == "1"

STEPS_TARGET = {"toy-s": 40, "toy-m": 30, "toy-moe": 30} if FAST else {
    "toy-s": 300,
    "toy-m": 160,
    "toy-moe": 160,
}
STEPS_DRAFT = 30 if FAST else 260
STEPS_MEDUSA = 30 if FAST else 200
STEPS_TDLM = 40 if FAST else 200


def to_hlo_text(lowered) -> str:
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> None:
    # keep_unused: single-input draft variants (feat/tok) ignore some args;
    # the rust caller feeds the full positional convention regardless.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))


# --------------------------------------------------------------------------
# executable builders — each returns (fn, example_args); all shapes static
# --------------------------------------------------------------------------


def _param_specs(params):
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in flatten_params(params)]


class TargetLowering:
    """Lowers the target-model executable family for one config."""

    def __init__(self, cfg: M.ModelConfig, params):
        self.cfg = cfg
        self.params = params
        self.flat = flatten_params(params)
        self.names = [n for n, _ in self.flat]
        self.specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in self.flat]

    def _unflatten(self, leaves):
        return unflatten_like(self.params, list(zip(self.names, leaves)))

    def prefill(self, p: int, b: int = 1):
        cfg = self.cfg
        np_ = len(self.specs)

        def fn(*args):
            params = self._unflatten(args[:np_])
            tokens, length = args[np_], args[np_ + 1]
            cache = M.init_cache(cfg, b)
            pos = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p)).astype(jnp.int32)
            bias = M.prefill_bias(cfg, p, length, b)
            logits, feats, cache, _, _ = M.forward(params, cfg, tokens, pos, pos, bias, cache)
            return logits, feats, cache

        ex = self.specs + [
            jax.ShapeDtypeStruct((b, p), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        return fn, ex

    def prefill_slot(self, p: int, b: int):
        """Prefill one sequence into slot `slot` of a batched cache."""
        cfg = self.cfg
        np_ = len(self.specs)

        def fn(*args):
            params = self._unflatten(args[:np_])
            cache_b, slot, tokens, length = args[np_ : np_ + 4]
            cache1 = M.init_cache(cfg, 1)
            pos = jnp.arange(p)[None, :].astype(jnp.int32)
            bias = M.prefill_bias(cfg, p, length, 1)
            logits, feats, cache1, _, _ = M.forward(params, cfg, tokens, pos, pos, bias, cache1)
            cache_b = jax.lax.dynamic_update_slice(
                cache_b, cache1, (0, 0, slot, 0, 0, 0)
            )
            return logits, feats, cache_b

        ex = self.specs + [
            jax.ShapeDtypeStruct((2, cfg.n_layers, b, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((1, p), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ]
        return fn, ex

    def decode(self, b: int = 1):
        cfg = self.cfg
        np_ = len(self.specs)

        def fn(*args):
            params = self._unflatten(args[:np_])
            cache, cache_len, token = args[np_ : np_ + 3]
            pos = cache_len[:, None]
            cols = jnp.arange(cfg.max_len)[None, None, :]
            bias = jnp.where(cols <= cache_len[:, None, None], 0.0, M.NEG).astype(jnp.float32)
            bias = jnp.broadcast_to(bias, (b, 1, cfg.max_len))
            logits, feats, cache, _, _ = M.forward(params, cfg, token, pos, pos, bias, cache)
            return logits, feats, cache

        ex = self.specs + [
            jax.ShapeDtypeStruct((2, cfg.n_layers, b, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ]
        return fn, ex

    def verify(self, t: int, a: int, b: int = 1):
        """Fused commit+verify (§Perf iteration 1): first compact the
        PREVIOUS round's accepted tree rows inside the cache
        (`commit_from_cache` — no tree K/V roundtrip, no extra dispatch),
        then run the new tree forward at the advanced boundary."""
        cfg = self.cfg
        np_ = len(self.specs)

        def fn(*args):
            params = self._unflatten(args[:np_])
            cache, old_len, prev_idx, prev_n, tokens, pos, bias = args[np_ : np_ + 7]
            cache = M.commit_from_cache(cfg, cache, old_len, prev_idx, prev_n)
            new_len = old_len + prev_n
            write_pos = new_len[:, None] + jnp.arange(t)[None, :]
            logits, feats, cache, _, _ = M.forward(
                params, cfg, tokens, pos, write_pos, bias, cache
            )
            return logits, feats, cache

        ex = self.specs + [
            jax.ShapeDtypeStruct((2, cfg.n_layers, b, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, a), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, t, cfg.max_len), jnp.float32),
        ]
        return fn, ex


class DraftLowering:
    """Lowers the EAGLE-head executable family for one (variant, target)."""

    def __init__(self, dcfg: D.DraftConfig, cfg: M.ModelConfig, dparams):
        self.dcfg = dcfg
        self.cfg = cfg
        self.dparams = dparams
        self.flat = flatten_params(dparams)
        self.names = [n for n, _ in self.flat]
        self.specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in self.flat]
        self.emb_spec = jax.ShapeDtypeStruct((cfg.vocab, cfg.d), jnp.float32)
        self.head_spec = jax.ShapeDtypeStruct((cfg.d, cfg.vocab), jnp.float32)

    def _unflatten(self, leaves):
        return unflatten_like(self.dparams, list(zip(self.names, leaves)))

    def prefill(self, p: int, b: int = 1):
        """Run the head over the committed prefix (teacher features), build
        its KV cache, and emit the first draft (f̂, logits) at the last
        valid position."""
        dcfg, cfg = self.dcfg, self.cfg
        nd = len(self.specs)

        def fn(*args):
            dparams = self._unflatten(args[:nd])
            tok_emb, lm_head, feats, tokens, length = args[nd : nd + 5]
            cache = D.init_draft_cache(cfg, b)
            pos = jnp.broadcast_to(jnp.arange(p)[None, :], (b, p)).astype(jnp.int32)
            bias = M.prefill_bias(cfg, p, length, b)
            f_hat, logits, cache = D.draft_forward(
                dparams, dcfg, cfg, tok_emb, lm_head, feats, tokens, pos, pos, bias, cache
            )
            last = length - 1  # [b]
            bidx = jnp.arange(b)
            return f_hat[bidx, last], logits[bidx, last], cache

        ex = self.specs + [
            self.emb_spec,
            self.head_spec,
            jax.ShapeDtypeStruct((b, p, cfg.d), jnp.float32),
            jax.ShapeDtypeStruct((b, p), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        return fn, ex

    def step(self, w: int, b: int = 1):
        """One draft-tree level: W frontier nodes with explicit bias/pos;
        K/V rows land at slots [write_base, write_base + W)."""
        dcfg, cfg = self.dcfg, self.cfg
        nd = len(self.specs)

        def fn(*args):
            dparams = self._unflatten(args[:nd])
            tok_emb, lm_head, cache, write_base, feats, tokens, pos, bias = args[nd : nd + 8]
            write_pos = write_base[:, None] + jnp.arange(w)[None, :]
            f_hat, logits, cache = D.draft_forward(
                dparams, dcfg, cfg, tok_emb, lm_head, feats, tokens, pos, write_pos, bias, cache
            )
            return f_hat, logits, cache

        ex = self.specs + [
            self.emb_spec,
            self.head_spec,
            jax.ShapeDtypeStruct((2, b, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b, w, cfg.d), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.int32),
            jax.ShapeDtypeStruct((b, w), jnp.int32),
            jax.ShapeDtypeStruct((b, w, cfg.max_len), jnp.float32),
        ]
        return fn, ex


# --------------------------------------------------------------------------
# checkpoint cache
# --------------------------------------------------------------------------


def _ckpt(path, trainer, template=None):
    if os.path.exists(path):
        flat = read_stensor(path)
        if template is None:
            return flat
        return unflatten_like(template, flat)
    res = trainer()
    write_stensor(path, flatten_params(res))
    return res


# --------------------------------------------------------------------------
# main build
# --------------------------------------------------------------------------


def build(out: str) -> None:
    t_start = time.time()
    os.makedirs(out, exist_ok=True)
    for sub in ("hlo", "weights", "workloads", "ckpt"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)
    log_entries = {}

    # ---- corpus + tokenizer ------------------------------------------------
    dialogues = data.gen_dialogues(N_DIALOGUES, SEED)
    corpus = data.corpus_text(dialogues)
    bpe = T.train_bpe(corpus, N_MERGES)
    with open(os.path.join(out, "vocab.json"), "w") as f:
        f.write(bpe.to_json())
    data.write_workloads(os.path.join(out, "workloads"))
    streams = [bpe.encode_dialogue(d["user"], d["asst"]) for d in dialogues]
    chunks = train.pack_chunks(streams, train.SEQ_LEN)
    print(f"[aot] corpus: {len(dialogues)} dialogues, {chunks.shape[0]} chunks, vocab {bpe.vocab_size}")

    configs = {
        "toy-s": replace(M.toy_s(), vocab=bpe.vocab_size),
        "toy-m": replace(M.toy_m(), vocab=bpe.vocab_size),
        "toy-moe": replace(M.toy_moe(), vocab=bpe.vocab_size),
    }

    manifest: dict = {
        "version": 1,
        "seed": SEED,
        "tokenizer": "vocab.json",
        "constants": {
            "prefill_p": PREFILL_P,
            "tree_t": TREE_T,
            "chain_t": CHAIN_T,
            "accept_a": ACCEPT_A,
            "draft_w": DRAFT_W,
            "verify_widths": sorted(VERIFY_WIDTHS),
            "draft_widths": sorted(DRAFT_WIDTHS),
        },
        "workloads": {
            "mtbench": "workloads/mtbench.json",
            "gsm8k": "workloads/gsm8k.json",
        },
        "models": {},
    }

    for name, cfg in configs.items():
        mdir = f"ckpt/{name}.s{STEPS_TARGET[name]}.stensor"
        tpl = M.init_params(cfg, jax.random.PRNGKey(0))
        params = _ckpt(
            os.path.join(out, mdir),
            lambda: train.train_target(cfg, chunks, STEPS_TARGET[name])[0],
            tpl,
        )
        write_stensor(os.path.join(out, f"weights/{name}.stensor"), flatten_params(params))

        tl = TargetLowering(cfg, params)
        exes = {}
        bs_list = [1] if name != "toy-s" else [1, 2, 3, 4]
        for b in bs_list:
            sfx = "" if b == 1 else f"_bs{b}"
            jobs = {f"decode{sfx}": tl.decode(b)}
            # the full verify-width family per batch size (CHAIN_T rides
            # along in VERIFY_WIDTHS, so the chain engines share it)
            for t in sorted(set(VERIFY_WIDTHS) | {CHAIN_T if b == 1 else TREE_T}):
                jobs[f"verify_t{t}{sfx}"] = tl.verify(t, ACCEPT_A, b)
            if b == 1:
                jobs["prefill"] = tl.prefill(PREFILL_P, 1)
            else:
                jobs[f"prefill_slot{sfx}"] = tl.prefill_slot(PREFILL_P, b)
            for ename, (fn, ex) in jobs.items():
                path = f"hlo/{name}.{ename}.hlo.txt"
                lower_to_file(fn, ex, os.path.join(out, path))
                exes[ename] = {"hlo": path, "bs": b}
                print(f"[aot] lowered {name}.{ename}")

        entry = {
            "config": {k: v for k, v in asdict(cfg).items()},
            "weights": f"weights/{name}.stensor",
            "param_names": tl.names,
            "executables": exes,
            "drafts": {},
        }

        # ---- draft heads ---------------------------------------------------
        feats = None
        variants = D.VARIANTS if name == "toy-s" else ("eagle",)
        for variant in variants:
            if feats is None:
                print(f"[aot] extracting features for {name} ...")
                feats = train.extract_features(params, cfg, chunks)
            dkey = f"{name}.{variant}"
            dcfg = D.DraftConfig(variant=variant, ffn=cfg.ffn)
            dtpl = D.init_draft_params(dcfg, cfg, jax.random.PRNGKey(1))
            dparams = _ckpt(
                os.path.join(out, f"ckpt/{dkey}.s{STEPS_DRAFT}.stensor"),
                lambda: train.train_draft_head(variant, params, cfg, chunks, feats, STEPS_DRAFT),
                dtpl,
            )
            write_stensor(os.path.join(out, f"weights/{dkey}.stensor"), flatten_params(dparams))
            acc = train.draft_top1_accuracy(dparams, variant, params, cfg, chunks, feats)
            log_entries[f"draft_acc.{dkey}"] = acc
            print(f"[aot] draft {dkey} top1-acc {acc:.3f}")

            dl = DraftLowering(dcfg, cfg, dparams)
            dexes = {}
            dbs = [1] if not (name == "toy-s" and variant == "eagle") else [1, 2, 3, 4]
            for b in dbs:
                sfx = "" if b == 1 else f"_bs{b}"
                # the full draft-step width family per batch size, so the
                # batch engine's per-level fits stay group-local at bs>1
                djobs = {f"step_w{w}{sfx}": dl.step(w, b) for w in sorted(DRAFT_WIDTHS)}
                if b == 1:
                    djobs["prefill"] = dl.prefill(PREFILL_P, 1)
                for ename, (fn, ex) in djobs.items():
                    path = f"hlo/{dkey}.{ename}.hlo.txt"
                    lower_to_file(fn, ex, os.path.join(out, path))
                    dexes[ename] = {"hlo": path, "bs": b}
                    print(f"[aot] lowered {dkey}.{ename}")
            entry["drafts"][variant] = {
                "weights": f"weights/{dkey}.stensor",
                "param_names": dl.names,
                "executables": dexes,
                "accuracy": acc,
            }

        # ---- Table-6 ablation: head trained on target-generated data --------
        if name == "toy-s":
            gen_path = os.path.join(out, f"ckpt/toy-s.eagle_gen.s{STEPS_DRAFT}.stensor")
            dcfg = D.DraftConfig(variant="eagle", ffn=cfg.ffn)
            dtpl = D.init_draft_params(dcfg, cfg, jax.random.PRNGKey(1))

            def train_gen():
                print("[aot] generating training data with the target LLM (Table 6) ...")
                prefixes = chunks[:160, :32]
                gen = train.generate_greedy(params, cfg, prefixes, train.SEQ_LEN - 32)
                gfeats = train.extract_features(params, cfg, gen)
                return train.train_draft_head("eagle", params, cfg, gen, gfeats, STEPS_DRAFT, seed=77)

            dparams_gen = _ckpt(gen_path, train_gen, dtpl)
            write_stensor(
                os.path.join(out, "weights/toy-s.eagle_gen.stensor"),
                flatten_params(dparams_gen),
            )
            # same architecture -> reuses the eagle executables, weights differ
            entry["drafts"]["eagle_gen"] = {
                "weights": "weights/toy-s.eagle_gen.stensor",
                "param_names": entry["drafts"]["eagle"]["param_names"],
                "executables": entry["drafts"]["eagle"]["executables"],
                "accuracy": train.draft_top1_accuracy(dparams_gen, "eagle", params, cfg, chunks, feats),
            }

        # ---- Medusa + token-draft-LM baselines (toy-s) -----------------------
        if name == "toy-s":
            mtpl = D.init_medusa_params(cfg, jax.random.PRNGKey(2))
            mparams = _ckpt(
                os.path.join(out, f"ckpt/toy-s.medusa.s{STEPS_MEDUSA}.stensor"),
                lambda: train.train_medusa(params, cfg, chunks, feats, STEPS_MEDUSA),
                mtpl,
            )
            write_stensor(os.path.join(out, "weights/toy-s.medusa.stensor"), flatten_params(mparams))
            mflat = flatten_params(mparams)

            def medusa_fn(*args):
                mp = unflatten_like(mparams, list(zip([n for n, _ in mflat], args[:-1])))
                return D.medusa_forward(mp, args[-1])

            mex = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in mflat] + [
                jax.ShapeDtypeStruct((1, cfg.d), jnp.float32)
            ]
            lower_to_file(medusa_fn, mex, os.path.join(out, "hlo/toy-s.medusa.hlo.txt"))
            print("[aot] lowered toy-s.medusa")
            entry["medusa"] = {
                "weights": "weights/toy-s.medusa.stensor",
                "param_names": [n for n, _ in mflat],
                "executables": {"heads": {"hlo": "hlo/toy-s.medusa.hlo.txt", "bs": 1}},
                "k": D.MEDUSA_K,
            }

            tcfg_tdlm = D.tdlm_config(cfg)
            ttpl = M.init_params(tcfg_tdlm, jax.random.PRNGKey(3))
            tdlm_params = _ckpt(
                os.path.join(out, f"ckpt/toy-s.tdlm.s{STEPS_TDLM}.stensor"),
                lambda: train.train_tdlm(cfg, chunks, STEPS_TDLM)[1],
                ttpl,
            )
            write_stensor(os.path.join(out, "weights/toy-s.tdlm.stensor"), flatten_params(tdlm_params))
            ttl = TargetLowering(tcfg_tdlm, tdlm_params)
            texes = {}
            for ename, (fn, ex) in {
                "prefill": ttl.prefill(PREFILL_P, 1),
                "decode": ttl.decode(1),
            }.items():
                path = f"hlo/toy-s.tdlm.{ename}.hlo.txt"
                lower_to_file(fn, ex, os.path.join(out, path))
                texes[ename] = {"hlo": path, "bs": 1}
                print(f"[aot] lowered toy-s.tdlm.{ename}")
            entry["tdlm"] = {
                "config": asdict(tcfg_tdlm),
                "weights": "weights/toy-s.tdlm.stensor",
                "param_names": ttl.names,
                "executables": texes,
            }

        manifest["models"][name] = entry

    # ---- int8 quantized target (Table 4 analog) ------------------------------
    Q.build_quant(out, manifest, configs["toy-s"])

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out, "train_log.json"), "w") as f:
        json.dump(log_entries, f, indent=1)
    print(f"[aot] done in {time.time() - t_start:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
