"""L2 — target-model compute graphs (S3).

LLaMA-style decoder-only transformer (RMSNorm, SwiGLU, RoPE) written as
pure functions over parameter pytrees, with:

* a **feature tap**: every forward returns both logits and the
  second-top-layer feature (here: the post-final-RMSNorm hidden state,
  i.e. the LM-head input) — the raw material of EAGLE;
* a **unified cache-forward**: prefill / single-token decode / tree verify
  are all the same function with different (positions, write slots,
  attention bias), so one code path is tested once and lowered many times;
* pluggable attention: the Pallas tree-attention kernel (L1) or the jnp
  oracle (`attn_impl`), numerically interchangeable (tested);
* an MoE variant (Mixtral analog) — dense top-2 mixture, fixed shapes.

KV caches are functional: forward returns the updated cache and the rust
coordinator (L3) swaps device buffers. Rejected draft-tree slots are simply
overwritten by later writes and are never attended (bias is built from
`cache_len`), so no scratch bookkeeping is needed.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .kernels.ref import tree_attention_ref
from .kernels.tree_attention import tree_attention

NEG = -1e30  # additive-mask "minus infinity" that survives fp32 arithmetic


@dataclass(frozen=True)
class ModelConfig:
    name: str = "toy-s"
    vocab: int = 1024
    d: int = 256
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 64
    ffn: int = 688
    max_len: int = 192  # committed + tree scratch slots (S_tot)
    rope_theta: float = 10000.0
    # MoE (Mixtral analog): n_experts=0 -> dense SwiGLU
    n_experts: int = 0
    top_k: int = 2
    attn_impl: str = "pallas"  # "pallas" | "ref"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


def toy_s() -> ModelConfig:
    return ModelConfig()


def toy_m() -> ModelConfig:
    return ModelConfig(name="toy-m", d=320, n_layers=5, n_heads=5, head_dim=64, ffn=864)


def toy_moe() -> ModelConfig:
    return ModelConfig(name="toy-moe", n_experts=4, top_k=2, ffn=344)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """He-ish init; LM head untied from the embedding (LLaMA convention)."""
    ks = jax.random.split(key, 2 + cfg.n_layers)

    def dense(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) * (2.0 / (i + o)) ** 0.5

    layers = []
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[2 + li], 8)
        hd = cfg.n_heads * cfg.head_dim
        layer = {
            "ln1": jnp.ones((cfg.d,), jnp.float32),
            "wq": dense(lk[0], cfg.d, hd),
            "wk": dense(lk[1], cfg.d, hd),
            "wv": dense(lk[2], cfg.d, hd),
            "wo": dense(lk[3], hd, cfg.d),
            "ln2": jnp.ones((cfg.d,), jnp.float32),
        }
        if cfg.is_moe:
            ek = jax.random.split(lk[4], cfg.n_experts * 3 + 1)
            layer["gate"] = dense(ek[0], cfg.d, cfg.n_experts)
            layer["w1"] = jnp.stack(
                [dense(ek[1 + 3 * e], cfg.d, cfg.ffn) for e in range(cfg.n_experts)]
            )
            layer["w2"] = jnp.stack(
                [dense(ek[2 + 3 * e], cfg.ffn, cfg.d) for e in range(cfg.n_experts)]
            )
            layer["w3"] = jnp.stack(
                [dense(ek[3 + 3 * e], cfg.d, cfg.ffn) for e in range(cfg.n_experts)]
            )
        else:
            layer["w1"] = dense(lk[5], cfg.d, cfg.ffn)
            layer["w2"] = dense(lk[6], cfg.ffn, cfg.d)
            layer["w3"] = dense(lk[7], cfg.d, cfg.ffn)
        layers.append(layer)
    return {
        "tok_emb": jax.random.normal(ks[0], (cfg.vocab, cfg.d), jnp.float32) * 0.02,
        "ln_f": jnp.ones((cfg.d,), jnp.float32),
        "lm_head": dense(ks[1], cfg.d, cfg.vocab),
        "layers": layers,
    }


def init_cache(cfg: ModelConfig, batch: int = 1) -> jnp.ndarray:
    """[2, L, B, S_tot, H, dh] stacked K/V cache."""
    return jnp.zeros(
        (2, cfg.n_layers, batch, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32
    )


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, T, H, dh], pos: [B, T] absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, :, None].astype(jnp.float32) * freqs[None, None, :]  # [B,T,half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(layer: dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ layer["w1"]) * (x @ layer["w3"])) @ layer["w2"]


def moe_mlp(layer: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dense top-k mixture: all experts computed, non-selected zero-weighted
    (fixed shapes for AOT; see DESIGN.md — the Tab.3 effect here comes from
    acceptance, not expert-paging bandwidth)."""
    gate = x @ layer["gate"]  # [B,T,E]
    # top-2 without lax.top_k: the `topk` HLO op is unknown to the
    # xla_extension 0.5.1 text parser the rust runtime uses (top_k=2 only)
    m1 = jnp.max(gate, axis=-1, keepdims=True)
    m2 = jnp.max(jnp.where(gate >= m1, NEG, gate), axis=-1, keepdims=True)
    masked = jnp.where(gate >= m2, gate, NEG)
    w = jax.nn.softmax(masked, axis=-1)  # [B,T,E]
    # [E,B,T,d] expert outputs
    outs = jnp.einsum(
        "ebtf,efd->ebtd",
        jax.nn.silu(jnp.einsum("btd,edf->ebtf", x, layer["w1"]))
        * jnp.einsum("btd,edf->ebtf", x, layer["w3"]),
        layer["w2"],
    )
    return jnp.einsum("bte,ebtd->btd", w, outs)


def _attention(cfg: ModelConfig, q, k_all, v_all, bias):
    if cfg.attn_impl == "pallas":
        return tree_attention(q, k_all, v_all, bias)
    return tree_attention_ref(q, k_all, v_all, bias)


# --------------------------------------------------------------------------
# unified cache-forward
# --------------------------------------------------------------------------


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B, T] int32
    pos: jnp.ndarray,  # [B, T] int32 absolute (RoPE) positions
    write_pos: jnp.ndarray,  # [B, T] int32 cache slots to write K/V into
    bias: jnp.ndarray,  # [B, T, S_tot] additive attention bias
    cache: jnp.ndarray,  # [2, L, B, S, H, dh]
):
    """Process T new tokens against the cache. Returns
    (logits [B,T,V], features [B,T,D], new_cache, tree_k, tree_v) where
    tree_k/v are this call's per-layer K/V rows [L,B,T,H,dh] (the verify
    path hands them to `commit`)."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens]  # [B,T,D]
    tree_ks, tree_vs = [], []
    batch_idx = jnp.arange(b)[:, None]  # [B,1]
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["ln1"])
        q = (h @ layer["wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
        if cache is None:
            # training path: attend over this call's K/V only (bias [B,T,T])
            o = _attention(cfg, q, k, v, bias)
        else:
            # scatter new K/V into this layer's cache rows
            cache = cache.at[0, li, batch_idx, write_pos].set(k)
            cache = cache.at[1, li, batch_idx, write_pos].set(v)
            tree_ks.append(k)
            tree_vs.append(v)
            o = _attention(cfg, q, cache[0, li], cache[1, li], bias)
        x = x + o.reshape(b, t, -1) @ layer["wo"]
        h2 = rmsnorm(x, layer["ln2"])
        x = x + (moe_mlp(layer, h2, cfg) if cfg.is_moe else swiglu(layer, h2))
    feats = rmsnorm(x, params["ln_f"])  # the EAGLE "feature"
    logits = feats @ params["lm_head"]
    if cache is None:
        return logits, feats, None, None, None
    return logits, feats, cache, jnp.stack(tree_ks), jnp.stack(tree_vs)


# --------------------------------------------------------------------------
# bias builders (in-graph; all shapes static)
# --------------------------------------------------------------------------


def prefill_bias(cfg: ModelConfig, p: int, length: jnp.ndarray, batch: int = 1):
    """Causal over the first p slots; columns beyond the written region are
    masked. `length` [B] masks padded prompt columns."""
    rows = jnp.arange(p)[None, :, None]  # [1,P,1]
    cols = jnp.arange(cfg.max_len)[None, None, :]  # [1,1,S]
    ok = (cols <= rows) & (cols < length[:, None, None])
    # self-attention always allowed so no row is fully masked
    ok = ok | (cols == rows)
    ok = jnp.broadcast_to(ok, (batch, p, cfg.max_len))
    return jnp.where(ok, 0.0, NEG).astype(jnp.float32)


def tree_bias(
    cfg: ModelConfig,
    t: int,
    cache_len: jnp.ndarray,  # [B] committed prefix length
    tree_mask: jnp.ndarray,  # [B, T, T] bool: node i attends tree node j
):
    """Tree nodes attend the committed prefix [0, cache_len) plus their
    ancestors inside the tree region [cache_len, cache_len+T)."""
    cols = jnp.arange(cfg.max_len)[None, None, :]  # [1,1,S]
    cl = cache_len[:, None, None]  # [B,1,1]
    prefix_ok = cols < cl
    rel = cols - cl  # position within tree region
    in_tree = (rel >= 0) & (rel < t)
    rel_c = jnp.clip(rel, 0, t - 1)
    tree_ok = jnp.take_along_axis(
        tree_mask, jnp.broadcast_to(rel_c, (tree_mask.shape[0], t, cfg.max_len)), axis=2
    )
    ok = prefix_ok | (in_tree & tree_ok)
    return jnp.where(ok, 0.0, NEG).astype(jnp.float32)


def commit(
    cfg: ModelConfig,
    cache: jnp.ndarray,  # [2, L, B, S, H, dh]
    cache_len: jnp.ndarray,  # [B]
    tree_k: jnp.ndarray,  # [L, B, T, H, dh] from verify
    tree_v: jnp.ndarray,
    accept_idx: jnp.ndarray,  # [B, A] tree-node indices (padded; see n_accept)
    n_accept: jnp.ndarray,  # [B]
):
    """Compact accepted tree rows to [cache_len, cache_len+n_accept).
    Padded entries scatter to the last slot (never attended: bias is built
    from the *new* cache_len which the coordinator tracks)."""
    b, a = accept_idx.shape
    batch_idx = jnp.arange(b)[:, None]
    j = jnp.arange(a)[None, :]
    dest = jnp.where(j < n_accept[:, None], cache_len[:, None] + j, cfg.max_len - 1)
    for li in range(cfg.n_layers):  # L is small & static
        rows_k = tree_k[li][batch_idx, accept_idx]  # [B,A,H,dh]
        rows_v = tree_v[li][batch_idx, accept_idx]
        cache = cache.at[0, li, batch_idx, dest].set(rows_k)
        cache = cache.at[1, li, batch_idx, dest].set(rows_v)
    return cache


def commit_from_cache(
    cfg: ModelConfig,
    cache: jnp.ndarray,  # [2, L, B, S, H, dh]
    cache_len: jnp.ndarray,  # [B] committed boundary of the PREVIOUS round
    accept_idx: jnp.ndarray,  # [B, A] accepted tree-node indices (ascending)
    n_accept: jnp.ndarray,  # [B]; 0 = no-op
):
    """§Perf variant of [`commit`]: the tree K/V rows already live in the
    cache at [cache_len, cache_len+T) (verify wrote them), so compaction is
    a gather/scatter *within* the cache — no tree_k/v host roundtrip and no
    separate executable dispatch (fused into the next verify call).
    Source index >= dest index for every row, so the functional
    gather-then-scatter is exact."""
    b, a = accept_idx.shape
    batch_idx = jnp.arange(b)[:, None]
    j = jnp.arange(a)[None, :]
    src = cache_len[:, None] + accept_idx  # [B, A]
    dest = jnp.where(j < n_accept[:, None], cache_len[:, None] + j, cfg.max_len - 1)
    for li in range(cfg.n_layers):
        rows_k = cache[0, li][batch_idx, src]  # [B,A,H,dh]
        rows_v = cache[1, li][batch_idx, src]
        cache = cache.at[0, li, batch_idx, dest].set(rows_k)
        cache = cache.at[1, li, batch_idx, dest].set(rows_v)
    return cache
