fn main() {}
