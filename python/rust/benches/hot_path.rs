fn main() {}
