fn main() { println!("repro"); }
