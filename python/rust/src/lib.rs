pub fn placeholder() {}
